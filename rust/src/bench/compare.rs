//! Fail-closed comparator over two `BenchReport`s — the engine behind
//! `elmo bench-diff`.
//!
//! The contract (docs/BENCHMARKS.md "How the gate decides"):
//!
//! * only **deterministic** metrics gate; wall-clock metrics produce
//!   trajectory notes, never violations (except corruption: a non-finite
//!   value anywhere is a violation — a bench that emits NaN is broken);
//! * `exact` gates fail on any drift, bit-for-bit for f64;
//! * `pct:X` gates fail when the regression is **X% or more** (the
//!   boundary itself fails — ties go to the gate, never to the bench);
//! * anything that prevents a trustworthy comparison fails closed:
//!   schema-version mismatch, bench-name mismatch, config-fingerprint
//!   drift, a deterministic metric missing from either side (dropped *or*
//!   newly added — both demand an explicit rebaseline), gate/type
//!   reclassification, a zero baseline under a pct gate (the percentage
//!   is undefined, so any regression on it is a violation).
//!
//! The one deliberately-soft edge: a `skipped` baseline against an `ok`
//! current run passes with a rebaseline note — that is the bootstrap path
//! for a bench whose baseline could not be measured yet.  The reverse
//! (ok baseline, skipped current) is a violation: the bench stopped
//! running, which is exactly the silent-skip failure this subsystem
//! exists to catch.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::report::{BenchReport, Gate, Kind, Status, Value, SCHEMA_VERSION};

/// One reason the comparison fails.  `metric` is the metric name, or a
/// `<bracketed>` pseudo-name for report-level problems.
#[derive(Clone, Debug)]
pub struct Violation {
    pub metric: String,
    pub why: String,
}

/// Outcome of `compare`: empty `violations` means the gate passes.
#[derive(Clone, Debug, Default)]
pub struct Comparison {
    pub violations: Vec<Violation>,
    /// Informational lines: wall-clock trajectory, improvements,
    /// rebaseline hints.  Never affect pass/fail.
    pub notes: Vec<String>,
    /// Deterministic metrics actually checked against a gate.
    pub gated: usize,
}

impl Comparison {
    pub fn passed(&self) -> bool {
        self.violations.is_empty()
    }

    fn fail(&mut self, metric: &str, why: impl Into<String>) {
        self.violations.push(Violation { metric: metric.to_string(), why: why.into() });
    }

    fn note(&mut self, line: impl Into<String>) {
        self.notes.push(line.into());
    }

    /// Human-readable rendering: notes first, then violations.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for n in &self.notes {
            let _ = writeln!(out, "note: {n}");
        }
        for v in &self.violations {
            let _ = writeln!(out, "VIOLATION {}: {}", v.metric, v.why);
        }
        out
    }
}

/// Compare `current` against `baseline`.  `threshold_override`, when
/// set, replaces X in every `pct:X` gate (the `--threshold` flag);
/// `exact` gates are never loosened.
pub fn compare(
    baseline: &BenchReport,
    current: &BenchReport,
    threshold_override: Option<f64>,
) -> Comparison {
    let mut c = Comparison::default();

    if baseline.schema != SCHEMA_VERSION {
        c.fail(
            "<schema>",
            format!("baseline schema {} != supported {SCHEMA_VERSION}", baseline.schema),
        );
    }
    if current.schema != SCHEMA_VERSION {
        c.fail(
            "<schema>",
            format!("current schema {} != supported {SCHEMA_VERSION}", current.schema),
        );
    }
    if baseline.name != current.name {
        c.fail(
            "<report>",
            format!("bench name mismatch: baseline `{}` vs current `{}`", baseline.name, current.name),
        );
    }
    if !c.violations.is_empty() {
        // schema/name problems make every further judgment untrustworthy
        return c;
    }

    match (baseline.status, current.status) {
        (Status::Ok, Status::Skipped) => {
            c.fail(
                "<status>",
                "current run is skipped while the baseline is ok — the bench stopped running \
                 (missing artifacts?); a skipped bench must not pass the gate",
            );
            c
        }
        (Status::Skipped, Status::Ok) => {
            c.note(
                "baseline is a skipped report: nothing to gate against; commit the fresh \
                 report as the new baseline to start gating (see docs/BENCHMARKS.md)",
            );
            c
        }
        (Status::Skipped, Status::Skipped) => {
            c.note("both reports are skipped — nothing measured, nothing gated");
            c
        }
        (Status::Ok, Status::Ok) => {
            if baseline.fingerprint != current.fingerprint {
                c.fail(
                    "<fingerprint>",
                    format!(
                        "config fingerprint drifted ({} -> {}): the benches measured different \
                         scenarios and cannot be compared; rebaseline",
                        baseline.fingerprint, current.fingerprint
                    ),
                );
            }
            compare_metrics(baseline, current, threshold_override, &mut c);
            c
        }
    }
}

fn compare_metrics(
    baseline: &BenchReport,
    current: &BenchReport,
    threshold_override: Option<f64>,
    c: &mut Comparison,
) {
    let cur: BTreeMap<&str, usize> =
        current.metrics.iter().enumerate().map(|(i, m)| (m.name.as_str(), i)).collect();
    let base: BTreeMap<&str, usize> =
        baseline.metrics.iter().enumerate().map(|(i, m)| (m.name.as_str(), i)).collect();

    for bm in &baseline.metrics {
        let Some(&ci) = cur.get(bm.name.as_str()) else {
            match bm.kind {
                Kind::Deterministic => c.fail(
                    &bm.name,
                    "deterministic metric missing from current report — a gated measurement \
                     silently disappeared; rebaseline explicitly if it was removed on purpose",
                ),
                Kind::WallClock => {
                    c.note(format!("wall-clock metric `{}` missing from current report", bm.name));
                }
            }
            continue;
        };
        let cm = &current.metrics[ci];

        if bm.kind != cm.kind {
            c.fail(
                &bm.name,
                format!(
                    "metric reclassified: {} in baseline, {} in current — rebaseline",
                    bm.kind.as_str(),
                    cm.kind.as_str()
                ),
            );
            continue;
        }
        if bm.value.type_str() != cm.value.type_str() {
            c.fail(
                &bm.name,
                format!(
                    "value type changed: {} in baseline, {} in current",
                    bm.value.type_str(),
                    cm.value.type_str()
                ),
            );
            continue;
        }
        // corruption fails closed regardless of kind: a bench emitting
        // non-finite numbers is not measuring
        if !bm.value.is_finite() || !cm.value.is_finite() {
            c.fail(
                &bm.name,
                format!(
                    "non-finite value (baseline {}, current {}) — corrupt report",
                    bm.value.render(),
                    cm.value.render()
                ),
            );
            continue;
        }

        match bm.kind {
            Kind::WallClock => {
                let (b, n) = (bm.value.as_f64(), cm.value.as_f64());
                let delta = if b != 0.0 { format!(" ({:+.2}%)", (n - b) / b * 100.0) } else { String::new() };
                c.note(format!("trajectory {}: {} -> {}{delta}", bm.name, bm.value.render(), cm.value.render()));
            }
            Kind::Deterministic => {
                if bm.gate != cm.gate {
                    c.fail(
                        &bm.name,
                        format!(
                            "gate changed: {} in baseline, {} in current — rebaseline",
                            bm.gate.render(),
                            cm.gate.render()
                        ),
                    );
                    continue;
                }
                c.gated += 1;
                match bm.gate {
                    Gate::RecordOnly => unreachable!("push/parse reject ungated deterministic metrics"), // elmo-lint: allow(panic-in-library) -- push() and parse() reject ungated deterministic metrics, so no constructed report reaches this arm
                    Gate::Exact => {
                        if !bm.value.bits_eq(cm.value) {
                            c.fail(
                                &bm.name,
                                format!(
                                    "deterministic drift: baseline {} != current {}",
                                    bm.value.render(),
                                    cm.value.render()
                                ),
                            );
                        }
                    }
                    Gate::Pct(x) => {
                        let x = threshold_override.unwrap_or(x);
                        gate_pct(bm.name.as_str(), bm.value, cm.value, x, c);
                    }
                }
            }
        }
    }

    for cm in &current.metrics {
        if base.contains_key(cm.name.as_str()) {
            continue;
        }
        match cm.kind {
            Kind::Deterministic => c.fail(
                &cm.name,
                "new deterministic metric absent from baseline — it cannot be gated until the \
                 baseline is regenerated; rebaseline",
            ),
            Kind::WallClock => {
                c.note(format!("new wall-clock metric `{}` = {}", cm.name, cm.value.render()));
            }
        }
    }
}

/// Pct gate: higher is worse (counts/bytes).  Regression >= x% fails;
/// a regression on a zero baseline is undefined-percentage and fails.
fn gate_pct(name: &str, baseline: Value, current: Value, x: f64, c: &mut Comparison) {
    let (b, n) = (baseline.as_f64(), current.as_f64());
    if n <= b {
        if n < b {
            c.note(format!(
                "{name}: improved {} -> {} ({:+.2}%) — consider rebaselining to ratchet",
                baseline.render(),
                current.render(),
                (n - b) / b * 100.0
            ));
        }
        return;
    }
    if b == 0.0 {
        c.fail(
            name,
            format!(
                "regression on a zero baseline (0 -> {}): percentage undefined, failing closed",
                current.render()
            ),
        );
        return;
    }
    let pct = (n - b) / b * 100.0;
    if pct >= x {
        c.fail(
            name,
            format!(
                "regression {:+.2}% >= gate {x}% ({} -> {})",
                pct,
                baseline.render(),
                current.render()
            ),
        );
    } else {
        c.note(format!(
            "{name}: {} -> {} ({:+.2}%) within the {x}% gate",
            baseline.render(),
            current.render(),
            pct
        ));
    }
}
