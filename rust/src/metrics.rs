//! Evaluation metrics: P@k and PSP@k (paper Appendix A), plus the running
//! top-k selection used by the chunked scorer.
//!
//! Scoring never materializes the full [n_test, L] logit matrix: the
//! coordinator streams label chunks through the `cls_fwd` executable and
//! folds each chunk into a per-row running top-k — the evaluation-side
//! analogue of the paper's chunked training.

/// Fixed-capacity running top-k of (score, label) pairs.
#[derive(Clone, Debug)]
pub struct TopK {
    pub k: usize,
    /// Sorted descending by score.
    items: Vec<(f32, u32)>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        TopK { k, items: Vec::with_capacity(k + 1) }
    }

    pub fn push(&mut self, score: f32, label: u32) {
        // Non-finite scores never enter the fold.  A NaN would satisfy no
        // `s >= score` comparison and land at rank 0, silently poisoning
        // P@k and serving results; ±inf only ever arise from upstream
        // numeric failure (finite weights x finite embeddings), so they
        // are dropped rather than ranked.
        if !score.is_finite() {
            return;
        }
        if self.items.len() == self.k
            && score <= self.items.last().map(|x| x.0).unwrap_or(f32::MIN)
        {
            return;
        }
        // ties keep the earlier-pushed item first (stable-sort order)
        let pos = self.items.partition_point(|&(s, _)| s >= score);
        self.items.insert(pos, (score, label));
        self.items.truncate(self.k);
    }

    pub fn labels(&self) -> Vec<u32> {
        self.items.iter().map(|&(_, l)| l).collect()
    }

    pub fn items(&self) -> &[(f32, u32)] {
        &self.items
    }
}

/// Precision@k for one instance: |top_k ∩ relevant| / k.
pub fn p_at_k(topk: &[u32], relevant: &[u32], k: usize) -> f64 {
    let hits = topk
        .iter()
        .take(k)
        .filter(|l| relevant.binary_search(l).is_ok())
        .count();
    hits as f64 / k as f64
}

/// Propensity-scored precision@k for one instance (Jain et al. 2016):
/// sum over predicted relevant labels of 1/p_l, normalized by the best
/// achievable value (the standard XC-repo normalization).
pub fn psp_at_k(
    topk: &[u32],
    relevant: &[u32],
    propensity: &[f64],
    k: usize,
) -> f64 {
    let num: f64 = topk
        .iter()
        .take(k)
        .filter(|l| relevant.binary_search(l).is_ok())
        .map(|&l| 1.0 / propensity[l as usize])
        .sum(); // elmo-lint: allow(float-order-hazard) -- serial fold over <= k terms in ranked topk order; order is part of the metric's definition
    // normalizer: the k largest 1/p over the instance's relevant labels
    let mut best: Vec<f64> =
        relevant.iter().map(|&l| 1.0 / propensity[l as usize]).collect();
    best.sort_by(|a, b| b.total_cmp(a));
    let den: f64 = best.iter().take(k).sum(); // elmo-lint: allow(float-order-hazard) -- serial fold over the k largest inverse propensities, fixed by the total_cmp sort above
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

/// Accumulates P@{1,3,5} and PSP@{1,3,5} over instances.
#[derive(Clone, Debug, Default)]
pub struct EvalAccum {
    pub n: usize,
    pub p: [f64; 3],
    pub psp: [f64; 3],
}

pub const KS: [usize; 3] = [1, 3, 5];

impl EvalAccum {
    pub fn add(&mut self, topk: &[u32], relevant: &[u32], propensity: &[f64]) {
        self.n += 1;
        for (i, &k) in KS.iter().enumerate() {
            self.p[i] += p_at_k(topk, relevant, k);
            self.psp[i] += psp_at_k(topk, relevant, propensity, k);
        }
    }

    pub fn p_at(&self, i: usize) -> f64 {
        100.0 * self.p[i] / self.n.max(1) as f64
    }

    pub fn psp_at(&self, i: usize) -> f64 {
        100.0 * self.psp[i] / self.n.max(1) as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "P@1 {:.2}  P@3 {:.2}  P@5 {:.2} | PSP@1 {:.2}  PSP@3 {:.2}  PSP@5 {:.2}",
            self.p_at(0), self.p_at(1), self.p_at(2),
            self.psp_at(0), self.psp_at(1), self.psp_at(2)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop_check;

    #[test]
    fn topk_matches_sort() {
        prop_check("topk_vs_sort", 100, |rng| {
            let n = 5 + rng.below(500);
            let k = 1 + rng.below(10);
            let scores: Vec<f32> =
                (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut tk = TopK::new(k);
            for (i, &s) in scores.iter().enumerate() {
                tk.push(s, i as u32);
            }
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
            let want: Vec<f32> =
                idx.iter().take(k.min(n)).map(|&i| scores[i]).collect();
            let got: Vec<f32> = tk.items().iter().map(|&(s, _)| s).collect();
            if got != want {
                return Err(format!("{got:?} != {want:?}"));
            }
            Ok(())
        });
    }

    /// Naive reference: stable sort descending by score, truncate to k.
    /// Stability matters — `TopK` keeps the earlier-pushed item ahead of
    /// (and in preference to) later equal-scored items, exactly like a
    /// stable descending sort.
    fn sort_and_truncate(stream: &[(f32, u32)], k: usize) -> Vec<(f32, u32)> {
        let mut v = stream.to_vec();
        v.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        v.truncate(k);
        v
    }

    #[test]
    fn topk_matches_sort_and_truncate_with_ties() {
        // coarse score grid -> ties are common; labels disambiguate order
        prop_check("topk_ties", 300, |rng| {
            let n = rng.below(64); // includes the empty stream
            let k = 1 + rng.below(12);
            let stream: Vec<(f32, u32)> = (0..n)
                .map(|i| ((rng.below(8) as f32) * 0.25 - 1.0, i as u32))
                .collect();
            let mut tk = TopK::new(k);
            for &(s, l) in &stream {
                tk.push(s, l);
            }
            let want = sort_and_truncate(&stream, k);
            if tk.items() != want.as_slice() {
                return Err(format!(
                    "n={n} k={k}: {:?} != {:?}",
                    tk.items(),
                    want
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn topk_k_exceeding_stream_returns_everything_sorted() {
        prop_check("topk_k_gt_n", 200, |rng| {
            let n = rng.below(10);
            let k = n + 1 + rng.below(10); // k strictly > stream length
            let stream: Vec<(f32, u32)> =
                (0..n).map(|i| (rng.normal_f32(0.0, 1.0), i as u32)).collect();
            let mut tk = TopK::new(k);
            for &(s, l) in &stream {
                tk.push(s, l);
            }
            if tk.items().len() != n {
                return Err(format!("kept {} of {n} items at k={k}", tk.items().len()));
            }
            if tk.items() != sort_and_truncate(&stream, k).as_slice() {
                return Err(format!("k>n order mismatch: {:?}", tk.items()));
            }
            if tk.labels().len() != n {
                return Err("labels() disagrees with items()".into());
            }
            Ok(())
        });
    }

    #[test]
    fn topk_skips_non_finite_scores() {
        // streams salted with NaN / ±inf must rank exactly like the same
        // stream with the non-finite entries filtered out
        prop_check("topk_non_finite", 300, |rng| {
            let n = rng.below(200);
            let k = 1 + rng.below(8);
            let stream: Vec<(f32, u32)> = (0..n)
                .map(|i| {
                    let s = match rng.below(10) {
                        0 => f32::NAN,
                        1 => f32::INFINITY,
                        2 => f32::NEG_INFINITY,
                        _ => rng.normal_f32(0.0, 1.0),
                    };
                    (s, i as u32)
                })
                .collect();
            let mut tk = TopK::new(k);
            for &(s, l) in &stream {
                tk.push(s, l);
            }
            let finite: Vec<(f32, u32)> =
                stream.iter().copied().filter(|(s, _)| s.is_finite()).collect();
            let want = sort_and_truncate(&finite, k);
            if tk.items() != want.as_slice() {
                return Err(format!("n={n} k={k}: {:?} != {want:?}", tk.items()));
            }
            if tk.items().iter().any(|(s, _)| !s.is_finite()) {
                return Err("non-finite score survived".into());
            }
            Ok(())
        });
    }

    #[test]
    fn topk_all_non_finite_stream_is_empty() {
        let mut tk = TopK::new(3);
        for (i, s) in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, f32::NAN]
            .into_iter()
            .enumerate()
        {
            tk.push(s, i as u32);
        }
        assert!(tk.items().is_empty(), "got {:?}", tk.items());
        assert!(tk.labels().is_empty());
        // and a later finite score still ranks normally
        tk.push(0.5, 9);
        assert_eq!(tk.items(), &[(0.5, 9)]);
    }

    #[test]
    fn topk_invariants_capacity_and_order() {
        prop_check("topk_invariants", 200, |rng| {
            let n = 1 + rng.below(300);
            let k = 1 + rng.below(8);
            let mut tk = TopK::new(k);
            for i in 0..n {
                tk.push(rng.normal_f32(0.0, 1.0), i as u32);
                // running invariants hold after EVERY push, not just at end
                if tk.items().len() > k.min(i + 1) {
                    return Err(format!("overfull at push {i}"));
                }
                if tk.items().windows(2).any(|w| w[0].0 < w[1].0) {
                    return Err(format!("unsorted after push {i}: {:?}", tk.items()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn p_at_k_basic() {
        // relevant sorted
        let rel = vec![2u32, 5, 9];
        assert_eq!(p_at_k(&[5, 1, 3], &rel, 1), 1.0);
        assert_eq!(p_at_k(&[1, 5, 3], &rel, 1), 0.0);
        assert!((p_at_k(&[5, 2, 3], &rel, 3) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn psp_weights_tail_up() {
        // two labels: head (p=0.9), tail (p=0.1). Predicting the tail
        // correctly scores higher than predicting the head correctly.
        let prop = vec![0.9, 0.1];
        let head = psp_at_k(&[0], &[0, 1], &prop, 1);
        let tail = psp_at_k(&[1], &[0, 1], &prop, 1);
        assert!(tail > head);
        // perfect normalization: predicting the single best label = 1.0
        assert!((psp_at_k(&[1], &[1], &prop, 1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn psp_in_unit_interval() {
        prop_check("psp_unit", 100, |rng| {
            let l = 50;
            let prop: Vec<f64> =
                (0..l).map(|_| rng.uniform().max(0.01)).collect();
            let mut rel: Vec<u32> =
                (0..1 + rng.below(5)).map(|_| rng.below(l) as u32).collect();
            rel.sort_unstable();
            rel.dedup();
            let topk: Vec<u32> =
                (0..5).map(|_| rng.below(l) as u32).collect();
            for k in [1, 3, 5] {
                let v = psp_at_k(&topk, &rel, &prop, k);
                if !(0.0..=1.0 + 1e-9).contains(&v) {
                    return Err(format!("psp@{k} = {v}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn accum_averages() {
        let mut a = EvalAccum::default();
        let prop = vec![0.5; 10];
        a.add(&[1, 2, 3, 4, 5], &[1], &prop);
        a.add(&[6, 2, 3, 4, 5], &[1], &prop);
        assert!((a.p_at(0) - 50.0).abs() < 1e-9);
    }
}
