//! `WeightStore`: the chunk-addressed host-side classifier state shared by
//! training, evaluation, and serving.
//!
//! The store owns every label-indexed buffer of the model — the weight
//! matrix `w` ([l_pad (+ scratch), d] row-major), the Renee momentum
//! buffer, the head-Kahan compensation buffer, and the label permutation —
//! and hands out *per-chunk views* to whoever executes kernels against it:
//!
//! * `policy::UpdatePolicy` impls read `chunk_w`/`chunk_mom`/`chunk_kahan`
//!   and stage updates as `StagedChunk`s that `commit_chunk` applies;
//! * `infer::ChunkScanner` scores through the read-only
//!   `ClassifierView::of_store` projection;
//! * `infer::Checkpoint` serializes `w_scored()`/`mom()`/`kahan()` and
//!   restores through `restore_sections`;
//! * `memmodel::host_bytes` charges the store's live buffers.
//!
//! Nothing outside this module indexes the raw vectors, which is what lets
//! later PRs reshape the storage (per-chunk precision mixes, sharding,
//! parallel chunk execution) without touching the training loop.

use crate::data::Csr;
use crate::err_shape;
use crate::error::Result;

/// Which optional buffers a precision policy asks the store to allocate
/// (see `policy::UpdatePolicy::buffers`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BufferSpec {
    /// Renee: an fp32 momentum buffer, same shape as `w`.
    pub momentum: bool,
    /// Head-Kahan: a compensation buffer for the head chunks.
    pub kahan: bool,
    /// Sampled: scratch rows appended past `l_pad` that gather zeros for
    /// unused shortlist slots and are never scattered back.
    pub scratch_rows: usize,
}

/// A policy's staged update for one chunk: new weights plus whichever
/// optional state buffers the policy owns.  Produced by
/// `UpdatePolicy::exec_chunk`, applied by `WeightStore::commit_chunk` —
/// either immediately (ELMO policies) or after the step-level overflow
/// decision (Renee's commit-on-clean-step).
#[derive(Clone, Debug)]
pub struct StagedChunk {
    pub w: Vec<f32>,
    pub kahan: Option<Vec<f32>>,
    pub mom: Option<Vec<f32>>,
}

/// Chunk-addressed classifier weight store.
#[derive(Clone, Debug)]
pub struct WeightStore {
    /// [l_pad + scratch_rows, d] row-major; values live on the owning
    /// policy's grid.
    w: Vec<f32>,
    /// Renee momentum (fp32), [l_pad, d] or empty.
    mom: Vec<f32>,
    /// Kahan compensation for head chunks, [l_pad, d] or empty.
    kahan_c: Vec<f32>,
    /// W row r holds label `label_order[r]`; identity except head-Kahan.
    label_order: Vec<u32>,
    /// Inverse permutation: label -> row.
    label_row: Vec<u32>,
    /// Real label count (`label_order.len()`).
    pub labels: usize,
    /// Labels padded up to a chunk multiple.
    pub l_pad: usize,
    pub d: usize,
    /// Label-chunk size Lc.
    pub chunk_size: usize,
    /// Leading chunks routed through the Kahan kernel (head-Kahan only).
    pub head_chunks: usize,
    /// Scratch rows appended past `l_pad` (Sampled only).
    pub scratch_rows: usize,
}

impl WeightStore {
    /// Allocate a zeroed store (zeros are representable on every grid).
    /// `label_order` must be a permutation of `0..labels`.
    pub fn new(
        labels: usize,
        d: usize,
        chunk_size: usize,
        label_order: Vec<u32>,
        head_chunks: usize,
        spec: BufferSpec,
    ) -> Result<Self> {
        if labels == 0 || d == 0 || chunk_size == 0 {
            return Err(err_shape!("weight store needs labels, d, chunk_size > 0"));
        }
        let l_pad = labels.div_ceil(chunk_size) * chunk_size;
        let mut store = WeightStore {
            w: vec![0.0; (l_pad + spec.scratch_rows) * d],
            mom: if spec.momentum { vec![0.0; l_pad * d] } else { Vec::new() },
            // allocated only when head chunks actually exist, so the
            // host-bytes accounting matches the policy's real footprint
            kahan_c: if spec.kahan && head_chunks > 0 {
                vec![0.0; l_pad * d]
            } else {
                Vec::new()
            },
            label_order: Vec::new(),
            label_row: vec![0; labels],
            labels,
            l_pad,
            d,
            chunk_size,
            head_chunks,
            scratch_rows: spec.scratch_rows,
        };
        store.set_label_order(&label_order)?;
        Ok(store)
    }

    /// Rebuild a store around checkpointed sections (read-only serving:
    /// no momentum/Kahan/scratch).  `w` must be the scored [l_pad, d]
    /// section; it is moved in, not copied — only one classifier-sized
    /// buffer ever exists on the load path.
    pub fn from_sections(
        labels: usize,
        d: usize,
        chunk_size: usize,
        head_chunks: usize,
        label_order: Vec<u32>,
        w: Vec<f32>,
    ) -> Result<Self> {
        if labels == 0 || d == 0 || chunk_size == 0 {
            return Err(err_shape!("weight store needs labels, d, chunk_size > 0"));
        }
        let l_pad = labels.div_ceil(chunk_size) * chunk_size;
        if w.len() != l_pad * d {
            return Err(err_shape!(
                "weight section has {} values, store geometry wants {} ({l_pad} x {d})",
                w.len(),
                l_pad * d
            ));
        }
        let mut store = WeightStore {
            w,
            mom: Vec::new(),
            kahan_c: Vec::new(),
            label_order: Vec::new(),
            label_row: vec![0; labels],
            labels,
            l_pad,
            d,
            chunk_size,
            head_chunks,
            scratch_rows: 0,
        };
        store.set_label_order(&label_order)?;
        Ok(store)
    }

    /// Number of label chunks per pass.
    pub fn chunks(&self) -> usize {
        self.l_pad / self.chunk_size
    }

    /// Padded rows past the real label count (training filler in the last
    /// chunk(s); the label permutation never maps onto them).
    pub fn pad_rows(&self) -> usize {
        self.l_pad - self.labels
    }

    /// Zero the padding rows of a staged chunk update before it commits.
    ///
    /// The per-chunk kernels update all `chunk_size` rows, padding
    /// included — left alone, pad rows drift away from zero (each sees a
    /// constant sigmoid(0) pull from its all-zero Y column), which (a)
    /// leaks a nonzero pad contribution into the input gradient and (b)
    /// makes the summed BCE loss depend on `l_pad`.  Pinning pad weights
    /// at zero keeps their xgrad contribution exactly 0 and their loss
    /// contribution the constant softplus(0) = ln 2 per (row, batch
    /// element) that `policy::padded_mean_loss` subtracts host-side.
    pub fn zero_staged_padding(&self, chunk: usize, staged: &mut StagedChunk) {
        let lo = chunk * self.chunk_size;
        if lo + self.chunk_size <= self.labels {
            return; // chunk holds only real labels
        }
        let start = (self.labels.max(lo) - lo) * self.d;
        staged.w[start..].fill(0.0);
        if let Some(k) = staged.kahan.as_mut() {
            k[start..].fill(0.0);
        }
        if let Some(m) = staged.mom.as_mut() {
            m[start..].fill(0.0);
        }
    }

    /// Flat index range of one chunk in `w`/`mom`/`kahan`.
    pub fn chunk_span(&self, chunk: usize) -> std::ops::Range<usize> {
        chunk * self.chunk_size * self.d..(chunk + 1) * self.chunk_size * self.d
    }

    /// One chunk of weights, [Lc, d].
    pub fn chunk_w(&self, chunk: usize) -> &[f32] {
        &self.w[self.chunk_span(chunk)]
    }

    /// One chunk of the momentum buffer (Renee).
    pub fn chunk_mom(&self, chunk: usize) -> &[f32] {
        debug_assert!(self.has_mom(), "policy without momentum asked for it");
        &self.mom[self.chunk_span(chunk)]
    }

    /// One chunk of the Kahan compensation buffer (head chunks).
    pub fn chunk_kahan(&self, chunk: usize) -> &[f32] {
        debug_assert!(self.has_kahan(), "policy without kahan state asked for it");
        &self.kahan_c[self.chunk_span(chunk)]
    }

    /// Apply a staged chunk update.  Buffers the staged update does not
    /// carry are left untouched.
    pub fn commit_chunk(&mut self, chunk: usize, staged: &StagedChunk) {
        let span = self.chunk_span(chunk);
        debug_assert_eq!(staged.w.len(), span.len());
        self.w[span.clone()].copy_from_slice(&staged.w);
        if let Some(c) = &staged.kahan {
            self.kahan_c[span.clone()].copy_from_slice(c);
        }
        if let Some(m) = &staged.mom {
            self.mom[span].copy_from_slice(m);
        }
    }

    pub fn has_mom(&self) -> bool {
        !self.mom.is_empty()
    }

    pub fn has_kahan(&self) -> bool {
        !self.kahan_c.is_empty()
    }

    /// The full weight array including any scratch rows.
    pub fn w(&self) -> &[f32] {
        &self.w
    }

    pub fn w_mut(&mut self) -> &mut [f32] {
        &mut self.w
    }

    /// The scored [l_pad, d] region (scratch rows excluded) — what the
    /// scanner scores and the checkpoint serializes.
    pub fn w_scored(&self) -> &[f32] {
        &self.w[..self.l_pad * self.d]
    }

    pub fn mom(&self) -> &[f32] {
        &self.mom
    }

    pub fn mom_mut(&mut self) -> &mut [f32] {
        &mut self.mom
    }

    pub fn kahan(&self) -> &[f32] {
        &self.kahan_c
    }

    pub fn kahan_mut(&mut self) -> &mut [f32] {
        &mut self.kahan_c
    }

    pub fn label_order(&self) -> &[u32] {
        &self.label_order
    }

    /// Row holding `label`'s weight vector.
    pub fn row_of_label(&self, label: u32) -> usize {
        self.label_row[label as usize] as usize
    }

    /// One weight row (any row below `l_pad + scratch_rows`).
    pub fn row(&self, row: usize) -> &[f32] {
        &self.w[row * self.d..(row + 1) * self.d]
    }

    pub fn write_row(&mut self, row: usize, values: &[f32]) {
        debug_assert_eq!(values.len(), self.d);
        self.w[row * self.d..(row + 1) * self.d].copy_from_slice(values);
    }

    /// Install a new label permutation and rebuild the inverse map.
    pub fn set_label_order(&mut self, order: &[u32]) -> Result<()> {
        if order.len() != self.labels {
            return Err(err_shape!(
                "label order has {} entries for {} labels",
                order.len(),
                self.labels
            ));
        }
        let mut seen = vec![false; self.labels];
        for &lab in order {
            if lab as usize >= self.labels || seen[lab as usize] {
                return Err(err_shape!("label order is not a permutation of 0..{}", self.labels));
            }
            seen[lab as usize] = true;
        }
        self.label_order = order.to_vec();
        for (row, &lab) in self.label_order.iter().enumerate() {
            self.label_row[lab as usize] = row as u32;
        }
        Ok(())
    }

    /// Dense Y block [rows.len(), width] for rows `lo..lo+width` of the
    /// permuted label space.
    pub fn y_block(&self, labels: &Csr, rows: &[u32], lo: usize, width: usize) -> Vec<f32> {
        let hi = lo + width;
        let mut y = vec![0.0f32; rows.len() * width];
        for (bi, &r) in rows.iter().enumerate() {
            for &lab in labels.row(r as usize) {
                let row = self.label_row[lab as usize] as usize;
                if (lo..hi).contains(&row) {
                    y[bi * width + (row - lo)] = 1.0;
                }
            }
        }
        y
    }

    /// Dense Y block for one training chunk (permutation-aware).
    pub fn y_chunk(&self, labels: &Csr, rows: &[u32], chunk: usize) -> Vec<f32> {
        self.y_block(labels, rows, chunk * self.chunk_size, self.chunk_size)
    }

    /// Overwrite model sections from a validated checkpoint.  Section
    /// lengths must match the current allocation exactly — the caller
    /// (`Checkpoint::restore`) has already matched policy and geometry.
    pub fn restore_sections(
        &mut self,
        w_scored: &[f32],
        mom: &[f32],
        kahan: &[f32],
        label_order: &[u32],
    ) -> Result<()> {
        if w_scored.len() != self.l_pad * self.d {
            return Err(err_shape!(
                "restore: w has {} values, store wants {}",
                w_scored.len(),
                self.l_pad * self.d
            ));
        }
        if mom.len() != self.mom.len() || kahan.len() != self.kahan_c.len() {
            return Err(err_shape!(
                "restore: optimizer sections ({}, {}) don't match store ({}, {})",
                mom.len(),
                kahan.len(),
                self.mom.len(),
                self.kahan_c.len()
            ));
        }
        self.set_label_order(label_order)?;
        self.w[..w_scored.len()].copy_from_slice(w_scored);
        self.mom.copy_from_slice(mom);
        self.kahan_c.copy_from_slice(kahan);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(labels: usize, d: usize, lc: usize, spec: BufferSpec) -> WeightStore {
        let order: Vec<u32> = (0..labels as u32).collect();
        WeightStore::new(labels, d, lc, order, 0, spec).unwrap()
    }

    #[test]
    fn geometry_pads_to_chunk_multiple() {
        let s = mk(1000, 4, 256, BufferSpec::default());
        assert_eq!(s.l_pad, 1024);
        assert_eq!(s.chunks(), 4);
        assert_eq!(s.w().len(), 1024 * 4);
        assert_eq!(s.w_scored().len(), 1024 * 4);
        assert!(!s.has_mom() && !s.has_kahan());
    }

    #[test]
    fn scratch_rows_extend_w_but_not_scored() {
        let s = mk(100, 3, 50, BufferSpec { scratch_rows: 7, ..Default::default() });
        assert_eq!(s.w().len(), (100 + 7) * 3);
        assert_eq!(s.w_scored().len(), 100 * 3);
        assert_eq!(s.scratch_rows, 7);
    }

    #[test]
    fn chunk_spans_tile_the_scored_region() {
        let s = mk(96, 2, 32, BufferSpec::default());
        let mut covered = 0;
        for c in 0..s.chunks() {
            let span = s.chunk_span(c);
            assert_eq!(span.start, covered);
            assert_eq!(s.chunk_w(c).len(), 32 * 2);
            covered = span.end;
        }
        assert_eq!(covered, s.w_scored().len());
    }

    #[test]
    fn commit_chunk_applies_each_staged_buffer() {
        let mut s = mk(
            64,
            2,
            32,
            BufferSpec { momentum: true, ..Default::default() },
        );
        let staged = StagedChunk {
            w: vec![1.5; 64],
            kahan: None,
            mom: Some(vec![-2.0; 64]),
        };
        s.commit_chunk(1, &staged);
        assert!(s.chunk_w(0).iter().all(|&v| v == 0.0));
        assert!(s.chunk_w(1).iter().all(|&v| v == 1.5));
        assert!(s.chunk_mom(1).iter().all(|&v| v == -2.0));
    }

    #[test]
    fn zero_staged_padding_pins_only_pad_rows() {
        // 90 labels, Lc=32 -> l_pad=96: chunk 2 holds rows 64..96, of
        // which 90..96 are padding (6 rows)
        let s = mk(90, 2, 32, BufferSpec { momentum: true, ..Default::default() });
        assert_eq!(s.pad_rows(), 6);
        let mut full = StagedChunk {
            w: vec![1.0; 32 * 2],
            kahan: None,
            mom: Some(vec![2.0; 32 * 2]),
        };
        // chunks 0/1 are all real labels: untouched
        let before = full.clone();
        s.zero_staged_padding(0, &mut full);
        s.zero_staged_padding(1, &mut full);
        assert_eq!(full.w, before.w);
        assert_eq!(full.mom, before.mom);
        // chunk 2: rows 26.. of the chunk (labels 90..96) zeroed
        s.zero_staged_padding(2, &mut full);
        let real = 26 * 2;
        assert!(full.w[..real].iter().all(|&v| v == 1.0));
        assert!(full.w[real..].iter().all(|&v| v == 0.0));
        let mom = full.mom.as_ref().unwrap();
        assert!(mom[..real].iter().all(|&v| v == 2.0));
        assert!(mom[real..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn zero_staged_padding_clears_a_mostly_pad_chunk() {
        // 20 labels, Lc=16 -> l_pad=32: chunk 1 is rows 16..32 with only
        // rows 16..20 real
        let s = mk(20, 3, 16, BufferSpec::default());
        assert_eq!(s.pad_rows(), 12);
        let mut st = StagedChunk { w: vec![7.0; 16 * 3], kahan: None, mom: None };
        s.zero_staged_padding(1, &mut st);
        assert!(st.w[..4 * 3].iter().all(|&v| v == 7.0));
        assert!(st.w[4 * 3..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn kahan_allocated_only_with_head_chunks() {
        let order: Vec<u32> = (0..64u32).collect();
        let spec = BufferSpec { kahan: true, ..Default::default() };
        let none = WeightStore::new(64, 2, 32, order.clone(), 0, spec).unwrap();
        assert!(!none.has_kahan());
        let some = WeightStore::new(64, 2, 32, order, 1, spec).unwrap();
        assert!(some.has_kahan());
        assert_eq!(some.kahan().len(), 64 * 2);
    }

    #[test]
    fn label_order_roundtrips_and_validates() {
        let mut s = mk(6, 1, 2, BufferSpec::default());
        s.set_label_order(&[5, 0, 3, 1, 4, 2]).unwrap();
        for (row, &lab) in s.label_order().iter().enumerate() {
            assert_eq!(s.row_of_label(lab), row);
        }
        assert!(s.set_label_order(&[0, 0, 3, 1, 4, 2]).is_err(), "duplicate");
        assert!(s.set_label_order(&[9, 0, 3, 1, 4, 2]).is_err(), "out of range");
        assert!(s.set_label_order(&[0, 1]).is_err(), "short");
    }

    #[test]
    fn y_chunk_places_positives_once_under_permutation() {
        let mut s = mk(8, 1, 4, BufferSpec::default());
        s.set_label_order(&[7, 6, 5, 4, 3, 2, 1, 0]).unwrap();
        let csr = Csr { indptr: vec![0, 2, 3], indices: vec![0, 7, 4] };
        let rows = [0u32, 1u32];
        let y0 = s.y_chunk(&csr, &rows, 0);
        let y1 = s.y_chunk(&csr, &rows, 1);
        // label 7 -> row 0 (chunk 0), label 0 -> row 7 (chunk 1),
        // label 4 -> row 3 (chunk 0)
        assert_eq!(y0, vec![1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0]);
        assert_eq!(y1, vec![0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0]);
        let total: f32 = y0.iter().chain(y1.iter()).sum();
        assert_eq!(total as usize, csr.indices.len());
    }

    #[test]
    fn row_read_write_roundtrip() {
        let mut s = mk(10, 3, 5, BufferSpec::default());
        s.write_row(4, &[1.0, 2.0, 3.0]);
        assert_eq!(s.row(4), &[1.0, 2.0, 3.0]);
        assert_eq!(s.row(3), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn restore_sections_validates_lengths() {
        let mut s = mk(4, 2, 2, BufferSpec::default());
        let order: Vec<u32> = vec![2, 3, 0, 1];
        let w = vec![0.5f32; 4 * 2];
        s.restore_sections(&w, &[], &[], &order).unwrap();
        assert_eq!(s.w_scored(), &w[..]);
        assert_eq!(s.label_order(), &order[..]);
        assert!(s.restore_sections(&w[..6], &[], &[], &order).is_err());
        assert!(s.restore_sections(&w, &[1.0], &[], &order).is_err());
    }

    #[test]
    fn from_sections_moves_weights_in() {
        let order: Vec<u32> = (0..6u32).collect();
        let w = vec![0.25f32; 8 * 3];
        let s = WeightStore::from_sections(6, 3, 4, 0, order, w.clone()).unwrap();
        assert_eq!(s.l_pad, 8);
        assert_eq!(s.w_scored(), &w[..]);
        assert!(WeightStore::from_sections(6, 3, 4, 0, (0..6).collect(), vec![0.0; 5]).is_err());
    }
}
