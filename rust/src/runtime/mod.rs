//! PJRT runtime: load AOT artifacts (HLO text) once, execute them from the
//! training hot path.  Adapted from /opt/xla-example/load_hlo — note the
//! gotchas documented there: HLO *text* interchange (not serialized proto),
//! outputs arrive as a 1-tuple/tuple literal because aot.py lowers with
//! `return_tuple=True`.

pub mod manifest;
pub mod pool;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Result, ResultExt};
use crate::{err_artifacts, err_runtime, err_shape};

pub use manifest::{ArtifactSpec, Dtype, Manifest, ModelConfig, TensorSpec};
pub use pool::{OrderedReducer, RuntimePool};

/// Owns the PJRT CPU client, the artifact registry, and an executable
/// cache (compile once per artifact, reuse across the whole run).
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Cumulative executions per artifact (perf accounting).
    pub exec_counts: HashMap<String, u64>,
}

impl Runtime {
    /// `dir` is the artifacts directory produced by `make artifacts`.
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::parse(&dir.join("manifest.txt"))
            .context("parsing artifacts/manifest.txt (run `make artifacts`)")?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| err_runtime!("PjRtClient::cpu: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            dir,
            exes: HashMap::new(),
            exec_counts: HashMap::new(),
        })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.manifest.config
    }

    /// Compile (or fetch cached) an artifact's executable.
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| err_artifacts!("unknown artifact `{name}`"))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| err_artifacts!("bad path"))?,
        )
        .map_err(|e| err_runtime!("loading {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| err_runtime!("compiling `{name}`: {e:?}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact on host slices; shapes come from the manifest.
    /// Returns the decomposed output tuple as literals.
    ///
    /// Implementation note: inputs go through
    /// `buffer_from_host_buffer` + `execute_b`.  The crate's
    /// `execute::<Literal>` convenience path leaks its internal
    /// host-to-device transfer (~input-size bytes per call; see
    /// EXPERIMENTS.md §Perf L3 iteration 4), which OOM-kills long
    /// training runs — the buffer path is leak-free and skips one copy.
    pub fn exec(&mut self, name: &str, args: &[Arg]) -> Result<Vec<xla::Literal>> {
        self.prepare(name)?;
        // Borrow the spec — this is the hottest path in the crate, and the
        // old `.clone()` here copied the spec's name/file strings and both
        // TensorSpec vectors on every kernel invocation.  The borrow of
        // `self.manifest` coexists with the uses of `self.client` /
        // `self.exes` / `self.exec_counts` below because they are disjoint
        // fields.
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| err_artifacts!("`{name}` vanished from the manifest after prepare()"))?;
        if args.len() != spec.inputs.len() {
            return Err(err_shape!(
                "`{name}` expects {} inputs, got {}",
                spec.inputs.len(),
                args.len()
            ));
        }
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(args.len());
        for (arg, tspec) in args.iter().zip(spec.inputs.iter()) {
            let buf = match (arg, tspec.dtype) {
                (Arg::F32(data), Dtype::F32) => {
                    if data.len() != tspec.numel() {
                        return Err(err_shape!(
                            "`{name}` input `{}`: {} elems for shape {:?}",
                            tspec.name, data.len(), tspec.dims
                        ));
                    }
                    self.client
                        .buffer_from_host_buffer(data, &tspec.dims, None)
                }
                (Arg::I32(data), Dtype::I32) => {
                    if data.len() != tspec.numel() {
                        return Err(err_shape!(
                            "`{name}` input `{}`: {} elems for shape {:?}",
                            tspec.name, data.len(), tspec.dims
                        ));
                    }
                    self.client
                        .buffer_from_host_buffer(data, &tspec.dims, None)
                }
                _ => {
                    return Err(err_shape!(
                        "`{name}` input `{}`: dtype mismatch (manifest {:?})",
                        tspec.name, tspec.dtype
                    ))
                }
            }
            .map_err(|e| err_runtime!("uploading `{}`: {e:?}", tspec.name))?;
            bufs.push(buf);
        }
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| err_runtime!("`{name}` missing from the executable cache after prepare()"))?;
        let result = exe
            .execute_b(&bufs)
            .map_err(|e| err_runtime!("executing `{name}`: {e:?}"))?;
        let row = &result[0];
        let outs: Vec<xla::Literal> = if row.len() == spec.outputs.len() && row.len() != 1 {
            // runtime untupled the result for us
            let mut v = Vec::with_capacity(row.len());
            for b in row {
                v.push(
                    b.to_literal_sync()
                        .map_err(|e| err_runtime!("fetching `{name}`: {e:?}"))?,
                );
            }
            v
        } else {
            // single (possibly tuple) output literal
            let lit = row[0]
                .to_literal_sync()
                .map_err(|e| err_runtime!("fetching `{name}`: {e:?}"))?;
            if spec.outputs.len() == 1 && !matches!(lit.shape(), Ok(xla::Shape::Tuple(_))) {
                vec![lit]
            } else {
                lit.to_tuple()
                    .map_err(|e| err_runtime!("decomposing `{name}` tuple: {e:?}"))?
            }
        };
        if outs.len() != spec.outputs.len() {
            return Err(err_shape!(
                "`{name}` returned {} outputs, manifest says {}",
                outs.len(),
                spec.outputs.len()
            ));
        }
        // `get_mut` first so the steady state allocates no counter key
        if let Some(c) = self.exec_counts.get_mut(name) {
            *c += 1;
        } else {
            self.exec_counts.insert(name.to_string(), 1);
        }
        Ok(outs)
    }

    /// True if the manifest contains this artifact.
    pub fn has(&self, name: &str) -> bool {
        self.manifest.artifact(name).is_some()
    }

    /// Compiled executables currently cached (per-worker accounting for
    /// the parallel chunk engine: each pool worker holds its own cache).
    pub fn cached_executables(&self) -> usize {
        self.exes.len()
    }
}

/// A runtime execution context: a `Runtime` plus an optional
/// `RuntimePool` for fanning data-independent label chunks out to worker
/// threads.  `pool: None` is the serial path — exactly the pre-pool
/// behavior.  Encoder kernels and non-chunk-shaped work always run on
/// `rt`; only the chunk loops (`policy::run_step`, `infer::ChunkScanner`)
/// consult `pool`.
///
/// This is internal plumbing: `session::Session` owns both pieces and
/// builds an `ExecCtx` per call (`Session::ctx`); public entrypoints take
/// `&mut Session`, never an `ExecCtx`.
pub struct ExecCtx<'a> {
    pub rt: &'a mut Runtime,
    pub pool: Option<&'a RuntimePool>,
}

impl<'a> ExecCtx<'a> {
    /// Serial execution on the caller's runtime (no pool).
    pub fn serial(rt: &'a mut Runtime) -> Self {
        ExecCtx { rt, pool: None }
    }

    /// Execution with an optional pool (`None` == `serial`).
    pub fn of(rt: &'a mut Runtime, pool: Option<&'a RuntimePool>) -> Self {
        ExecCtx { rt, pool }
    }

    /// Effective chunk-loop parallelism.
    pub fn workers(&self) -> usize {
        self.pool.map_or(1, |p| p.workers())
    }
}

/// A host-side input argument; the manifest supplies shape and dtype.
#[derive(Clone, Copy)]
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

/// Copy a literal's f32 payload out to a Vec.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| err_runtime!("to_vec f32: {e:?}"))
}

/// Read a shape-(1,) scalar.
pub fn to_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(to_vec_f32(lit)?[0])
}

/// Load a raw little-endian f32 binary (enc_init_*.bin).
pub fn load_f32_bin(path: impl AsRef<Path>) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path.as_ref())
        .map_err(|e| err_artifacts!("reading {:?}: {e}", path.as_ref()))?;
    if bytes.len() % 4 != 0 {
        return Err(err_artifacts!("file size not a multiple of 4"));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}
