//! The parallel chunk execution engine: a pool of worker threads, each
//! owning its **own** `Runtime` (PJRT client + executable cache) over the
//! same artifacts directory, plus the deterministic ordered reduction that
//! makes parallel execution bit-identical to the serial chunk loop.
//!
//! Label chunks are data-independent — the only cross-chunk state is the
//! *ordered* fold of xgrad / loss / gmax and the store commit (see
//! `store.rs`).  The design exploits that:
//!
//! * `RuntimePool` fans jobs out to N persistent workers.  Each worker
//!   constructs its `Runtime` inside its own thread and the client never
//!   crosses a thread boundary, sidestepping any `Send`/`Sync` question on
//!   the underlying xla handles.  Executable caches persist across steps,
//!   so each worker compiles an artifact once per run, exactly like the
//!   serial path.
//! * Jobs are `'static` closures over *owned* chunk inputs; results come
//!   back on a caller-owned channel in completion order.
//! * `OrderedReducer` re-serializes completion order into strict chunk
//!   order, so the coordinating thread folds results 0, 1, 2, ... no
//!   matter which worker finished first — f32 accumulation order, store
//!   commit order, and Renee's staged-chunk indexing are all preserved
//!   bit-for-bit.  `rust/tests/parallel_parity.rs` pins this.
//!
//! Consumers: `policy::run_step_pooled` (training) and
//! `ChunkScanner::scan` (eval + serving), both reached through a pooled
//! `session::Session` (`--workers N` on the CLI; the default 1 is a
//! pool-less session, i.e. the serial path).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

use crate::err_runtime;
use crate::error::Result;

use super::Runtime;

/// A unit of work executed on a worker's own `Runtime`.  Jobs report
/// results through whatever channel they captured at submission.
pub type Job = Box<dyn FnOnce(&mut Runtime) + Send + 'static>;

struct WorkerHandle {
    /// `None` once the pool starts shutting down.
    tx: Option<Sender<Job>>,
    handle: Option<JoinHandle<()>>,
}

/// N worker threads, each with a private `Runtime` over one artifacts dir.
pub struct RuntimePool {
    workers: Vec<WorkerHandle>,
    dir: PathBuf,
}

impl RuntimePool {
    /// Spawn `workers` threads; each constructs its own PJRT runtime over
    /// `dir` and reports readiness before `new` returns, so a missing or
    /// corrupt artifacts dir fails here rather than mid-step.
    pub fn new(dir: impl AsRef<Path>, workers: usize) -> Result<Self> {
        if workers == 0 {
            return Err(err_runtime!("runtime pool needs at least one worker"));
        }
        let dir = dir.as_ref().to_path_buf();
        let (boot_tx, boot_rx) = channel::<Result<()>>();
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let (tx, rx) = channel::<Job>();
            let worker_dir = dir.clone();
            let boot = boot_tx.clone();
            // elmo-lint: allow(raw-thread-spawn) -- the RuntimePool IS the sanctioned spawn site; every other module fans out through it
            let handle = std::thread::Builder::new()
                .name(format!("elmo-chunk-worker-{i}"))
                .spawn(move || {
                    // the Runtime is born and dies on this thread
                    let mut rt = match Runtime::new(&worker_dir) {
                        Ok(rt) => {
                            let _ = boot.send(Ok(()));
                            rt
                        }
                        Err(e) => {
                            let _ = boot.send(Err(e));
                            return;
                        }
                    };
                    drop(boot);
                    while let Ok(job) = rx.recv() {
                        job(&mut rt);
                    }
                })
                .map_err(|e| err_runtime!("spawning chunk worker {i}: {e}"))?;
            handles.push(WorkerHandle { tx: Some(tx), handle: Some(handle) });
        }
        drop(boot_tx);
        let pool = RuntimePool { workers: handles, dir };
        for _ in 0..workers {
            match boot_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    return Err(e.context("initializing a pool worker's PJRT runtime"))
                }
                Err(_) => {
                    return Err(err_runtime!("a pool worker exited before reporting readiness"))
                }
            }
        }
        Ok(pool)
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// The artifacts directory every worker loaded.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Queue `job` on worker `worker % workers()`.  Chunk loops use a
    /// stable `chunk % workers` assignment so each worker sees the same
    /// artifacts every step (one compilation per worker per artifact).
    pub fn submit(&self, worker: usize, job: Job) -> Result<()> {
        let idx = worker % self.workers.len();
        // senders live until Drop; a None here means submit-after-shutdown
        match self.workers[idx].tx.as_ref() {
            Some(tx) => tx
                .send(job)
                .map_err(|_| err_runtime!("runtime pool worker {idx} has shut down")),
            None => Err(err_runtime!("runtime pool worker {idx} is shutting down")),
        }
    }

    /// Precompile `names` on every worker (parallel warmup), surfacing the
    /// first failure.  Optional — workers also compile lazily on first use.
    pub fn prepare(&self, names: &[String]) -> Result<()> {
        let (tx, rx) = channel::<Result<()>>();
        for w in 0..self.workers.len() {
            let names = names.to_vec();
            let tx = tx.clone();
            self.submit(
                w,
                Box::new(move |rt| {
                    let mut r = Ok(());
                    for n in &names {
                        if let Err(e) = rt.prepare(n) {
                            r = Err(e);
                            break;
                        }
                    }
                    let _ = tx.send(r);
                }),
            )?;
        }
        drop(tx);
        for _ in 0..self.workers.len() {
            match rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => return Err(e),
                Err(_) => return Err(err_runtime!("a pool worker hung up during warmup")),
            }
        }
        Ok(())
    }
}

impl Drop for RuntimePool {
    fn drop(&mut self) {
        // close every job channel first so workers drain and exit ...
        for w in &mut self.workers {
            w.tx = None;
        }
        // ... then join them (PJRT teardown happens on the worker thread)
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

/// Re-serializes out-of-order completions into strict index order.
///
/// `push` buffers `(idx, item)` pairs arriving in ANY order and invokes
/// the apply callback for every contiguously-available index in 0, 1, 2,
/// ... order.  The fold a caller runs inside `apply` is therefore
/// *identical* to a serial loop's, regardless of worker completion order —
/// this is the whole determinism argument of the parallel engine, and it
/// is unit-tested host-side with shuffled arrival orders (no artifacts
/// needed).
pub struct OrderedReducer<T> {
    next: usize,
    pending: BTreeMap<usize, T>,
}

impl<T> OrderedReducer<T> {
    pub fn new() -> Self {
        OrderedReducer { next: 0, pending: BTreeMap::new() }
    }

    /// Accept one completed item; `apply(idx, item)` fires zero or more
    /// times, always at the current fold frontier and in index order.
    pub fn push(&mut self, idx: usize, item: T, mut apply: impl FnMut(usize, T)) {
        debug_assert!(
            idx >= self.next && !self.pending.contains_key(&idx),
            "duplicate or stale chunk index {idx}"
        );
        self.pending.insert(idx, item);
        while let Some(item) = self.pending.remove(&self.next) {
            apply(self.next, item);
            self.next += 1;
        }
    }

    /// Indices folded so far (== n when every item 0..n has been applied).
    pub fn emitted(&self) -> usize {
        self.next
    }

    /// True when nothing is buffered waiting for an earlier index.
    pub fn is_drained(&self) -> bool {
        self.pending.is_empty()
    }
}

impl<T> Default for OrderedReducer<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn reducer_emits_in_index_order_for_any_arrival_order() {
        for case in 0..50u64 {
            let mut rng = Rng::new(0xC0FFEE + case);
            let n = 1 + rng.below(24);
            let mut arrival: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut arrival);
            let mut red = OrderedReducer::new();
            let mut seen: Vec<(usize, usize)> = Vec::new();
            for &idx in &arrival {
                red.push(idx, idx * 10, |i, v| seen.push((i, v)));
            }
            assert_eq!(red.emitted(), n);
            assert!(red.is_drained());
            let want: Vec<(usize, usize)> = (0..n).map(|i| (i, i * 10)).collect();
            assert_eq!(seen, want, "arrival {arrival:?}");
        }
    }

    #[test]
    fn reducer_holds_back_until_the_frontier_arrives() {
        let mut red = OrderedReducer::new();
        let mut seen = Vec::new();
        red.push(2, "c", |i, v| seen.push((i, v)));
        red.push(1, "b", |i, v| seen.push((i, v)));
        assert!(seen.is_empty(), "nothing emits before index 0");
        assert!(!red.is_drained());
        red.push(0, "a", |i, v| seen.push((i, v)));
        assert_eq!(seen, vec![(0, "a"), (1, "b"), (2, "c")]);
        assert!(red.is_drained());
        assert_eq!(red.emitted(), 3);
    }
}
