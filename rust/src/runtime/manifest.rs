//! Text-manifest parser for the artifact registry emitted by `aot.py`.
//!
//! Format (line-based, whitespace-separated; no JSON because the offline
//! image vendors no serde):
//!
//! ```text
//! config vocab=1024 d=64 ... psize=139264 hist_bins=64 hist_lo=-40
//! artifact name=enc_fwd_bf16 file=enc_fwd_bf16.hlo.txt
//! in packed f32 139264
//! in tokens i32 32x16
//! out emb f32 32x64
//! ```

use std::path::Path;

use crate::err_artifacts;
use crate::error::Result;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => Err(err_artifacts!("unknown dtype `{other}`")),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: Dtype,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Global model constants shared by aot.py and the coordinator.
#[derive(Clone, Debug, Default)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d: usize,
    pub seq: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub batch: usize,
    pub psize: usize,
    pub hist_bins: usize,
    pub hist_lo: i32,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub config: ModelConfig,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn parse(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| err_artifacts!("reading {path:?}: {e}"))?;
        Self::parse_str(&text)
    }

    pub fn parse_str(text: &str) -> Result<Self> {
        let mut m = Manifest::default();
        for (ln, line) in text.lines().enumerate() {
            let toks: Vec<&str> = line.split_whitespace().collect();
            let err = |msg: &str| err_artifacts!("manifest line {}: {msg}", ln + 1);
            match toks.first().copied() {
                None => {}
                Some("config") => {
                    for kv in &toks[1..] {
                        let (k, v) = kv
                            .split_once('=')
                            .ok_or_else(|| err("bad config kv"))?;
                        let c = &mut m.config;
                        match k {
                            "vocab" => c.vocab = parse_num(v, &err)?,
                            "d" => c.d = parse_num(v, &err)?,
                            "seq" => c.seq = parse_num(v, &err)?,
                            "layers" => c.layers = parse_num(v, &err)?,
                            "heads" => c.heads = parse_num(v, &err)?,
                            "ffn" => c.ffn = parse_num(v, &err)?,
                            "batch" => c.batch = parse_num(v, &err)?,
                            "psize" => c.psize = parse_num(v, &err)?,
                            "hist_bins" => c.hist_bins = parse_num(v, &err)?,
                            "hist_lo" => c.hist_lo = parse_num(v, &err)?,
                            _ => {} // forward-compatible
                        }
                    }
                }
                Some("artifact") => {
                    let mut name = None;
                    let mut file = None;
                    for kv in &toks[1..] {
                        match kv.split_once('=') {
                            Some(("name", v)) => name = Some(v.to_string()),
                            Some(("file", v)) => file = Some(v.to_string()),
                            _ => return Err(err("bad artifact kv")),
                        }
                    }
                    m.artifacts.push(ArtifactSpec {
                        name: name.ok_or_else(|| err("missing name"))?,
                        file: file.ok_or_else(|| err("missing file"))?,
                        inputs: vec![],
                        outputs: vec![],
                    });
                }
                Some(dir @ ("in" | "out")) => {
                    if toks.len() != 4 {
                        return Err(err("in/out needs 4 tokens"));
                    }
                    let spec = TensorSpec {
                        name: toks[1].to_string(),
                        dtype: Dtype::parse(toks[2])?,
                        dims: toks[3]
                            .split('x')
                            .map(|d| d.parse::<usize>())
                            .collect::<std::result::Result<_, _>>()
                            .map_err(|_| err("bad dims"))?,
                    };
                    let art = m
                        .artifacts
                        .last_mut()
                        .ok_or_else(|| err("in/out before artifact"))?;
                    if dir == "in" {
                        art.inputs.push(spec);
                    } else {
                        art.outputs.push(spec);
                    }
                }
                Some(other) => return Err(err(&format!("unknown directive `{other}`"))),
            }
        }
        if m.config.d == 0 || m.config.batch == 0 {
            return Err(err_artifacts!("manifest missing config line"));
        }
        Ok(m)
    }
}

/// Parse one config value with the line-scoped error constructor (the
/// config fields mix `usize` and `i32`, hence the generic).
fn parse_num<T: std::str::FromStr>(
    v: &str,
    err: &impl Fn(&str) -> crate::error::Error,
) -> Result<T> {
    v.parse()
        .map_err(|_| err(&format!("bad config value `{v}`")))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
config vocab=1024 d=64 seq=16 layers=2 heads=4 ffn=128 batch=32 psize=139264 hist_bins=64 hist_lo=-40
artifact name=cls_fwd_1024 file=cls_fwd_1024.hlo.txt
in w f32 1024x64
in x f32 32x64
out logits f32 32x1024
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse_str(SAMPLE).unwrap();
        assert_eq!(m.config.d, 64);
        assert_eq!(m.config.hist_lo, -40);
        let a = m.artifact("cls_fwd_1024").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].dims, vec![1024, 64]);
        assert_eq!(a.inputs[0].numel(), 65536);
        assert_eq!(a.outputs[0].dtype, Dtype::F32);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse_str("bogus line\n").is_err());
        assert!(Manifest::parse_str("in x f32 4\n").is_err());
        assert!(Manifest::parse_str("config d=64\nartifact name=a\n").is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.txt");
        if p.exists() {
            let m = Manifest::parse(&p).unwrap();
            assert!(m.artifacts.len() >= 20);
            assert!(m.artifact("enc_fwd_bf16").is_some());
            assert_eq!(m.config.psize % 8192, 0);
        }
    }
}
