//! ELMO: Efficiency via Low-precision and Peak Memory Optimization in Large
//! Output Spaces (ICML 2025) — a three-layer Rust + JAX + Pallas
//! reproduction.
//!
//! Layer map (see DESIGN.md and docs/ARCHITECTURE.md):
//! * L3 (this crate): training coordinator over an explicit
//!   coordinator → policy → store → runtime stack — `policy` holds one
//!   `UpdatePolicy` per precision, `store` the chunk-addressed
//!   `WeightStore` shared by train / eval / infer — plus the data
//!   pipeline, metrics, memory model, and CLI.
//! * L2 (`python/compile/model.py`): jax encoder fwd/bwd, AOT-lowered to
//!   HLO text under `artifacts/`.
//! * L1 (`python/compile/kernels/`): Pallas kernels — the fused XMC
//!   classifier update (Algorithm 1), the parametric quantizer, Kahan-AdamW.
//!
//! Python never runs on the training path: `runtime` loads the HLO
//! artifacts through the PJRT C API (`xla` crate) once; afterwards the
//! whole training loop is rust calling compiled executables.
//!
//! Trained models outlive the process through `infer`: a versioned
//! checkpoint format, a read-only `Predictor` over the shared chunked
//! top-k scanner, and a micro-batching request queue (`elmo predict` /
//! `elmo serve-bench`).  The online layer on top is `serve`:
//! label-sharded scoring with a deterministic cross-shard merge, a
//! bounded admission queue with deadline-aware flushing, and a seeded
//! open-loop load harness (`elmo serve`).
//!
//! The public execution API is the `session` facade: a `Session` owns the
//! runtime and the optional chunk-execution pool, every training / eval /
//! serving entrypoint takes `&mut Session`, and `config::RunSpec` is the
//! declarative run description behind `--config`.  All library errors are
//! the typed `elmo::Error` (`error` module) — `anyhow` is a consumer-side
//! convenience for the binary and the test/bench harnesses only.
//!
//! The invariants behind those guarantees are machine-checked: `lint`
//! implements `elmo lint` (docs/LINTS.md), a dependency-free static
//! analysis pass over `rust/src` that CI runs as a blocking step.
//!
//! Cross-cutting observability lives in `obs` (docs/OBSERVABILITY.md):
//! deterministic Chrome-trace spans on the injectable clock, a unified
//! metrics registry, and the `elmo trace-check` reconciliation validator.

// Rule 3 (panic-in-library) mirrored at the compiler level: clippy warns
// on unwrap/expect in non-test library code, and CI runs clippy with
// `-D warnings`.  clippy.toml exempts `#[cfg(test)]` code.
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod infer;
pub mod lint;
pub mod memmodel;
pub mod metrics;
pub mod numerics;
pub mod obs;
pub mod policy;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod store;
pub mod util;

pub use config::RunSpec;
pub use error::{Error, Result};
pub use session::{KernelSet, Session, SessionBuilder};
