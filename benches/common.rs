//! Shared helpers for the table/figure bench harnesses.
//!
//! Every bench prints, for each table/figure row the paper reports, the
//! paper's number next to what this reproduction measures (accuracy from
//! real scaled training, memory from the analytic model at paper scale,
//! epoch time measured on this CPU testbed).  The *shape* — who wins, by
//! roughly what factor — is the reproduction target; absolute numbers
//! differ because the substrate is an emulator, not an H100.

#![allow(dead_code)]

use elmo::coordinator::{evaluate, EvalReport, Precision, TrainConfig};
use elmo::data::{self, Dataset, Profile};
use elmo::memmodel::{self, MemParams, Method};
use elmo::Session;

pub const ART: &str = "artifacts";

pub fn have_artifacts() -> bool {
    std::path::Path::new(&format!("{ART}/manifest.txt")).exists()
}

/// Epoch override for quick runs: ELMO_EPOCHS=1 cargo bench ...
///
/// Absent means the default; present-but-unparsable is a loud failure,
/// never a silent fallback — `ELMO_EPOCHS=ten` running the full default
/// epoch count would silently invalidate the quick run it asked for.
pub fn epochs_or(default: usize) -> usize {
    match std::env::var("ELMO_EPOCHS") {
        Err(std::env::VarError::NotPresent) => default,
        Err(std::env::VarError::NotUnicode(v)) => {
            panic!("ELMO_EPOCHS is not valid unicode: {v:?}")
        }
        Ok(v) => v.parse().unwrap_or_else(|_| {
            panic!("ELMO_EPOCHS=`{v}` is not a valid epoch count (expected an unsigned integer)")
        }),
    }
}

pub struct RunResult {
    pub report: EvalReport,
    pub epoch_secs: f64,
    pub mean_loss: f64,
    pub overflow_steps: usize,
    pub trainer_chunks: usize,
}

/// Train `epochs` on a profile with a precision policy, return final eval.
pub fn run_training(
    sess: &mut Session,
    ds: &Dataset,
    precision: Precision,
    chunk: usize,
    epochs: usize,
    eval_rows: usize,
) -> anyhow::Result<RunResult> {
    let cfg = TrainConfig {
        precision,
        chunk_size: chunk,
        epochs,
        dropout_emb: 0.3,
        ..TrainConfig::default()
    };
    run_training_cfg(sess, ds, cfg, eval_rows)
}

pub fn run_training_cfg(
    sess: &mut Session,
    ds: &Dataset,
    cfg: TrainConfig,
    eval_rows: usize,
) -> anyhow::Result<RunResult> {
    let epochs = cfg.epochs;
    let mut tr = sess.trainer(ds, cfg)?;
    // compile executables outside the timed epochs
    sess.prepare(&tr.required_kernels())?;
    let mut secs = 0.0;
    let mut loss = 0.0;
    let mut oflow = 0;
    for epoch in 0..epochs {
        let st = tr.run_epoch(sess, ds, epoch)?;
        secs += st.secs;
        loss = st.mean_loss;
        oflow += st.overflow_steps;
    }
    let report = evaluate(sess, &tr, ds, eval_rows)?;
    Ok(RunResult {
        report,
        epoch_secs: secs / epochs.max(1) as f64,
        mean_loss: loss,
        overflow_steps: oflow,
        trainer_chunks: tr.chunks(),
    })
}

/// Paper-scale peak memory (GiB) for a dataset profile + method.
pub fn paper_mem_gib(prof: &Profile, method: Method, chunks: u64) -> f64 {
    memmodel::peak_gib(method, &MemParams::from_profile(prof, chunks))
}

pub fn method_of(p: Precision) -> Method {
    match p {
        Precision::Renee => Method::Renee,
        Precision::Bf16 => Method::ElmoBf16,
        Precision::Fp8 | Precision::Fp8HeadKahan => Method::ElmoFp8,
        Precision::Fp32 => Method::Fp32,
        Precision::Sampled => Method::Sampled,
    }
}

pub fn dataset(name: &str, seed: u64) -> Dataset {
    data::generate(&data::profile(name).expect("profile"), seed)
}

pub fn fmt_p(r: &EvalReport) -> [String; 3] {
    [
        format!("{:.2}", r.p[0]),
        format!("{:.2}", r.p[1]),
        format!("{:.2}", r.p[2]),
    ]
}

pub fn fmt_psp(r: &EvalReport) -> [String; 3] {
    [
        format!("{:.2}", r.psp[0]),
        format!("{:.2}", r.psp[1]),
        format!("{:.2}", r.psp[2]),
    ]
}

pub fn mmss(secs: f64) -> String {
    elmo::util::mmss(secs)
}

/// Artifact gate for benches that need compiled HLO: prints the banner
/// AND drops a `"status": "skipped"` `BENCH_<name>.json`, so the CI perf
/// gate can tell a bench that could not run from one that ran clean —
/// a silent exit-0 skip is indistinguishable from a pass (ISSUE 6).
pub fn skip_banner(name: &str) -> bool {
    if !have_artifacts() {
        println!("{name}: artifacts missing — run `make artifacts` first");
        emit_skipped_report(name);
        return true;
    }
    false
}

/// Write the skipped-status report for an artifact-gated bench.  Report
/// IO failure must not mask the (successful, deliberately skipped) bench
/// run, so it only warns.
pub fn emit_skipped_report(name: &str) {
    let rep =
        elmo::bench::BenchReport::skipped(name, &format!("{name} artifact-gated harness v1"));
    let path = format!("BENCH_{name}.json");
    if let Err(e) = rep.save(&path) {
        eprintln!("warning: could not write {path}: {e}");
    } else {
        println!("{name}: wrote {path} (status: skipped)");
    }
}
