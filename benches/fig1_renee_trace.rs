//! Figure 1: memory trace of Renee at 3M labels, batch 128 — the
//! allocation timeline whose peak motivates the whole paper.

mod common;

use elmo::memmodel::{schedule, MemParams, Method, GIB};
use elmo::util::{gib, print_table};

fn main() {
    let p = MemParams::paper_example();
    let tr = schedule(Method::Renee, &p);
    println!("== Figure 1: Renee memory trace (3M labels, b=128, BERT-base) ==\n");
    let rows: Vec<Vec<String>> = tr
        .series()
        .into_iter()
        .map(|(ev, live)| {
            let (phase, tensor) = ev.split_once(':').unwrap();
            let bar_len = (live as f64 / GIB / 2.0) as usize;
            vec![
                phase.to_string(),
                tensor.to_string(),
                gib(live),
                "#".repeat(bar_len),
            ]
        })
        .collect();
    print_table(&["phase", "event", "live GiB", "trace"], &rows);
    println!("\npeak: {} GiB   (paper: ~39.7 GiB; Sec 4.4 init 17.9 GiB)", gib(tr.peak()));
    println!(
        "observations reproduced: (1) the FP16 weight copy persists the whole\n\
         step; (2) the gradient is computed in 16-bit then UPCAST to 32-bit;\n\
         (3) all transients stack on top of live activations at one point."
    );
}
