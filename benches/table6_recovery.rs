//! Table 6: precision-recovery strategies on LF-AmazonTitles-1.3M —
//! post-hoc classifier refinement on a frozen encoder, and Kahan summation
//! for the top-20% head labels (paper Appendix D).

mod common;

use common::*;
use elmo::Session;
use elmo::coordinator::{evaluate, Precision, TrainConfig, Trainer};
use elmo::data::Batcher;
use elmo::util::print_table;

fn main() -> anyhow::Result<()> {
    if skip_banner("table6_recovery") {
        return Ok(());
    }
    println!("== Table 6: post-hoc refinement & head-label Kahan (LF-AT-1.3M scaled) ==\n");
    let ds = dataset("lf-amazontitles1.3m", 0);
    let mut sess = Session::open(ART)?;
    let epochs = epochs_or(4);

    let mut rows = Vec::new();
    let paper: &[(&str, f64, f64, f64, f64)] = &[
        ("Renee", 56.04, 49.91, 45.32, 19.9),
        ("BF16 (ELMO)", 56.14, 49.86, 45.25, 6.61),
        ("Float8 (ELMO)", 54.97, 48.41, 43.82, 4.31),
        ("Post-Hoc", 55.4, 48.87, 44.34, 4.31),
        ("Head Kahan", 55.6, 49.38, 44.88, 4.65),
    ];

    // base rows: renee / bf16 / fp8
    let mut fp8_trainer: Option<Trainer> = None;
    for (i, pr) in [Precision::Renee, Precision::Bf16, Precision::Fp8]
        .iter()
        .enumerate()
    {
        let cfg = TrainConfig {
            precision: *pr,
            chunk_size: if *pr == Precision::Renee { 2048 } else { 1024 },
            epochs,
            dropout_emb: 0.3,
            ..TrainConfig::default()
        };
        let mut tr = Trainer::new(&sess, &ds, cfg)?;
        for epoch in 0..epochs {
            tr.run_epoch(&mut sess, &ds, epoch)?;
        }
        let rep = evaluate(&mut sess, &tr, &ds, 512)?;
        let [p1, p3, p5] = fmt_p(&rep);
        let (pn, pp1, pp3, pp5, pmtr) = paper[i];
        rows.push(vec![
            pn.to_string(), p1, p3, p5,
            format!("{pp1}/{pp3}/{pp5} @ {pmtr} GiB"),
        ]);
        if *pr == Precision::Fp8 {
            fp8_trainer = Some(tr);
        }
    }

    // Post-hoc: freeze the encoder of the FP8 checkpoint, fine-tune the
    // classifier in fp32 for one epoch (lr_enc = 0, wd = 0 emulates the
    // frozen encoder; classifier rows loaded chunk-at-a-time as in D.1)
    {
        let fp8 = fp8_trainer.as_ref().unwrap();
        let cfg = TrainConfig {
            precision: Precision::Fp32,
            chunk_size: 1024,
            epochs: 1,
            lr_enc: 0.0,
            wd_enc: 0.0,
            lr_cls: 0.01,
            dropout_emb: 0.0,
            ..TrainConfig::default()
        };
        let mut tr = Trainer::new(&sess, &ds, cfg)?;
        tr.store.w_mut().copy_from_slice(fp8.store.w());
        tr.enc_p.copy_from_slice(&fp8.enc_p);
        let mut b = Batcher::new(ds.train.n, tr.batch, 9);
        while let Some((rws, _)) = b.next_batch() {
            tr.step(&mut sess, &ds, &rws)?;
        }
        let rep = evaluate(&mut sess, &tr, &ds, 512)?;
        let [p1, p3, p5] = fmt_p(&rep);
        let (pn, pp1, pp3, pp5, pmtr) = paper[3];
        rows.push(vec![pn.to_string(), p1, p3, p5, format!("{pp1}/{pp3}/{pp5} @ {pmtr} GiB")]);
    }

    // Head Kahan: FP8 everywhere except BF16+Kahan for top-20% labels
    {
        let cfg = TrainConfig {
            precision: Precision::Fp8HeadKahan,
            chunk_size: 512,
            epochs,
            head_frac: 0.2,
            dropout_emb: 0.3,
            ..TrainConfig::default()
        };
        let res = run_training_cfg(&mut sess, &ds, cfg, 512)?;
        let [p1, p3, p5] = fmt_p(&res.report);
        let (pn, pp1, pp3, pp5, pmtr) = paper[4];
        rows.push(vec![pn.to_string(), p1, p3, p5, format!("{pp1}/{pp3}/{pp5} @ {pmtr} GiB")]);
    }

    print_table(&["method", "P@1", "P@3", "P@5", "paper P@1/3/5 @ M_tr"], &rows);
    println!(
        "\nshape checks: both recovery strategies land between FP8 and BF16;\n\
         Head-Kahan needs no second training stage (paper Appendix D.2)."
    );
    Ok(())
}
