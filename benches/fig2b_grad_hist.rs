//! Figure 2b: exponent histogram of classifier logit-gradients vs the
//! representable ranges of FP8 E5M2 ([-16, 15] incl. subnormals) and
//! E4M3 ([-9, 8]) — the measurement that justifies keeping gradients in
//! BF16 (paper Sec 4.3).

mod common;

use common::*;
use elmo::Session;
use elmo::coordinator::eval::diagnostics_hist;
use elmo::coordinator::{Precision, TrainConfig, Trainer};
use elmo::data::Batcher;

fn main() -> anyhow::Result<()> {
    if skip_banner("fig2b_grad_hist") {
        return Ok(());
    }
    println!("== Figure 2b: classifier gradient exponent histogram ==\n");
    let ds = dataset("lf-amazontitles131k", 0);
    let mut sess = Session::open(ART)?;
    let cfg = TrainConfig {
        precision: Precision::Bf16,
        chunk_size: 512,
        epochs: 1,
        dropout_emb: 0.3,
        ..TrainConfig::default()
    };
    let mut tr = Trainer::new(&sess, &ds, cfg)?;
    // short warmup so gradients are taken mid-training like the paper
    let mut b = Batcher::new(ds.train.n, tr.batch, 0);
    for _ in 0..24 {
        let (rows, _) = b.next_batch().unwrap();
        tr.step(&mut sess, &ds, &rows)?;
    }
    let (hg, _, _) = diagnostics_hist(&mut sess, &tr, &ds)?;
    let lo = sess.config().hist_lo;
    let total: f32 = hg.iter().sum();

    println!("exp2 bucket | count | share");
    let mut below_e5m2 = 0.0f32;
    let mut below_e4m3 = 0.0f32;
    for (i, &c) in hg.iter().enumerate() {
        let e = lo + i as i32;
        if c > 0.0 {
            let share = c / total * 100.0;
            let bar = "#".repeat((share / 2.0) as usize);
            println!("2^{e:>4}      | {c:>7} | {share:5.1}% {bar}");
        }
        // E5M2 subnormal floor 2^-16, E4M3 floor 2^-9: gradients below
        // these round to zero in the respective fp8 format
        if e < -16 {
            below_e5m2 += c;
        }
        if e < -9 {
            below_e4m3 += c;
        }
    }
    println!(
        "\ngradients lost to zero in E5M2 (exp < -16): {:.1}%  (paper: ~20%)",
        below_e5m2 / total * 100.0
    );
    println!(
        "gradients lost to zero in E4M3 (exp <  -9): {:.1}%  (paper: ~90%)",
        below_e4m3 / total * 100.0
    );
    println!("=> gradients must stay BF16; FP8 is for weights/inputs only (Sec 4.3).");
    Ok(())
}
