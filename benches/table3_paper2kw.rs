//! Table 3: LF-Paper2Keywords-8.6M — the contributed dataset where Renee's
//! FP16 mixed precision collapses (gradient overflow in the classifier
//! input over 8.6M labels) while ELMO BF16 even beats FLOAT32.

mod common;

use common::*;
use elmo::Session;
use elmo::coordinator::Precision;
use elmo::util::print_table;

fn main() -> anyhow::Result<()> {
    if skip_banner("table3_paper2kw") {
        return Ok(());
    }
    println!("== Table 3: LF-Paper2Keywords-8.6M (scaled stand-in, L=16384) ==\n");
    let ds = dataset("lf-paper2kw8.6m", 0);
    let mut sess = Session::open(ART)?;
    let epochs = epochs_or(4);

    // paper rows: (method, P@1, P@3, P@5, M_tr)
    let paper: &[(&str, Precision, f64, f64, f64, f64)] = &[
        ("FLOAT32", Precision::Fp32, 43.60, 32.13, 26.02, 58.44),
        ("RENEE", Precision::Renee, 17.65, 11.78, 9.23, 105.64),
        ("ELMO(BF16)", Precision::Bf16, 45.4, 33.58, 27.18, 18.8),
        ("ELMO(FP8)", Precision::Fp8, 43.4, 31.59, 25.38, 9.02),
    ];
    let mut rows = Vec::new();
    for &(pname, pr, pp1, pp3, pp5, pmtr) in paper {
        let chunk = if pr == Precision::Renee { 2048 } else { 2048 };
        let res = run_training(&mut sess, &ds, pr, chunk, epochs, 512)?;
        let [p1, p3, p5] = fmt_p(&res.report);
        let mem = paper_mem_gib(&ds.profile, method_of(pr), res.trainer_chunks as u64);
        rows.push(vec![
            pname.to_string(),
            p1,
            p3,
            p5,
            format!("{:.2}", mem),
            format!("{pp1}/{pp3}/{pp5}"),
            format!("{pmtr:.2}"),
            format!("{}", res.overflow_steps),
        ]);
    }
    print_table(
        &[
            "method", "P@1", "P@3", "P@5", "M_tr model GiB",
            "paper P@1/3/5", "paper M_tr", "oflow steps",
        ],
        &rows,
    );
    println!(
        "\nshape checks: BF16 >= FLOAT32 (SR regularization); Renee pays for\n\
         FP16 input-gradient overflow (oflow steps > 0 -> skipped updates);\n\
         memory order FLOAT32 > Renee >> BF16 > FP8 at paper scale."
    );
    Ok(())
}
