//! Table 5: commodity-hardware (RTX 4060 Ti) recipe — BF16 encoder (torchao
//! FP8 unavailable on consumer GPUs) + FP8 classifier.  Memory from the
//! model at paper scale; epoch time measured here on the scaled stand-in.

mod common;

use common::*;
use elmo::Session;
use elmo::coordinator::{Precision, TrainConfig};
use elmo::data;
use elmo::memmodel::{peak_gib, MemParams, Method};
use elmo::util::print_table;

fn main() -> anyhow::Result<()> {
    if skip_banner("table5_commodity") {
        return Ok(());
    }
    println!("== Table 5: commodity-HW recipe (FP8 classifier, BF16 encoder) ==\n");
    // paper rows: dataset -> (epoch time mm:ss, M_tr GB)
    let paper: &[(&str, &str, f64)] = &[
        ("lf-amazontitles1.3m", "57:36", 5.45),
        ("amazon3m", "121:17", 8.46),
        ("lf-paper2kw8.6m", "229:24", 10.49),
    ];
    let mut sess = Session::open(ART)?;
    let epochs = epochs_or(1);
    let mut rows = Vec::new();
    for &(name, paper_time, paper_mem) in paper {
        let prof = data::profile(name).unwrap();
        let ds = data::generate(&prof, 0);
        let cfg = TrainConfig {
            precision: Precision::Fp8,
            enc_override: Some("bf16"), // the commodity recipe
            chunk_size: 1024,
            epochs,
            dropout_emb: 0.3,
            ..TrainConfig::default()
        };
        let res = run_training_cfg(&mut sess, &ds, cfg, 256)?;
        let mem = peak_gib(
            Method::Fp8ClsBf16Enc,
            &MemParams::from_profile(&prof, res.trainer_chunks as u64),
        );
        rows.push(vec![
            prof.paper_name.to_string(),
            paper_time.to_string(),
            format!("{paper_mem:.2}"),
            mmss(res.epoch_secs),
            format!("{mem:.2}"),
            format!("{:.2}", res.report.p[0]),
        ]);
    }
    print_table(
        &[
            "dataset",
            "paper epoch",
            "paper M_tr GB",
            "ours epoch (CPU, scaled)",
            "model M_tr GiB",
            "ours P@1",
        ],
        &rows,
    );
    println!("\nepoch times are not comparable in absolute terms (4060Ti vs CPU emulation);");
    println!("the reproduced shape is the memory column: ~5-11 GiB fits an 8-16 GB card.");
    Ok(())
}
