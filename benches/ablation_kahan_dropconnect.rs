//! Ablation bench (DESIGN.md design choices, beyond the paper's tables):
//!
//! 1. encoder Kahan compensation ON vs OFF at BF16 — the paper argues pure
//!    BF16 "can no longer progress" without compensation (Sec 4.1); here
//!    the fp32-encoder run is the reference and the BF16+Kahan run must
//!    track it (the no-Kahan ablation is the L1 kernel's use_kahan=False
//!    path on BF16-grid state, exercised in python tests; at the rust
//!    level we compare the two lowered encoder configs).
//! 2. classifier DropConnect (Appendix H) 0.0 vs 0.3 vs 0.6 — in-kernel
//!    weight dropout should act as a regularizer without extra memory.

mod common;

use common::*;
use elmo::Session;
use elmo::coordinator::{Precision, TrainConfig};
use elmo::util::print_table;

fn main() -> anyhow::Result<()> {
    if skip_banner("ablation_kahan_dropconnect") {
        return Ok(());
    }
    let epochs = epochs_or(4);
    let ds = dataset("lf-amazontitles131k", 0);
    let mut sess = Session::open(ART)?;

    println!("== Ablation A: encoder state precision (classifier fixed BF16+SR) ==\n");
    let mut rows = Vec::new();
    for (label, enc) in [
        ("fp32 AdamW encoder", "fp32"),
        ("BF16 + Kahan encoder", "bf16"),
    ] {
        let cfg = TrainConfig {
            precision: Precision::Bf16,
            enc_override: Some(if enc == "fp32" { "fp32" } else { "bf16" }),
            chunk_size: 1024,
            epochs,
            dropout_emb: 0.3,
            ..TrainConfig::default()
        };
        let res = run_training_cfg(&mut sess, &ds, cfg, 512)?;
        let [p1, p3, p5] = fmt_p(&res.report);
        rows.push(vec![
            label.to_string(), p1, p3, p5,
            format!("{:.5}", res.mean_loss), mmss(res.epoch_secs),
        ]);
    }
    print_table(&["encoder", "P@1", "P@3", "P@5", "final loss", "epoch"], &rows);
    println!("expected: BF16+Kahan within noise of fp32 (paper Sec 4.1).\n");

    println!("== Ablation B: classifier DropConnect (Appendix H) ==\n");
    let mut rows = Vec::new();
    for p in [0.0f32, 0.3, 0.6] {
        let cfg = TrainConfig {
            precision: Precision::Bf16,
            chunk_size: 1024,
            epochs,
            dropout_emb: 0.3,
            dropout_cls: p,
            ..TrainConfig::default()
        };
        let res = run_training_cfg(&mut sess, &ds, cfg, 512)?;
        let [p1, p3, p5] = fmt_p(&res.report);
        rows.push(vec![
            format!("{p:.1}"), p1, p3, p5,
            format!("{:.2}", res.report.psp[0]),
        ]);
    }
    print_table(&["dropconnect p", "P@1", "P@3", "P@5", "PSP@1"], &rows);
    println!("\nthe mask lives inside the matmul kernel: no weight copy, zero");
    println!("extra HBM (the memory claim of Appendix H holds by construction).");
    Ok(())
}
