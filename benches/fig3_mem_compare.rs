//! Figure 3: side-by-side memory snapshots of Renee vs ELMO across one
//! training step (init / forward / backward / update phases).

mod common;

use elmo::memmodel::{schedule, MemParams, Method};
use elmo::util::{gib, print_table};

fn main() {
    let p = MemParams::paper_example();
    println!(
        "== Figure 3: Renee vs ELMO phase memory @ 3M labels, b=128, k={} chunks ==\n",
        p.chunks
    );
    let methods = [Method::Renee, Method::ElmoBf16, Method::ElmoFp8];
    let traces: Vec<_> = methods.iter().map(|&m| schedule(m, &p)).collect();

    // collect the union of phase prefixes in order
    for (m, tr) in methods.iter().zip(traces.iter()) {
        println!("-- {} --", m.label());
        let rows: Vec<Vec<String>> = tr
            .phase_peaks()
            .into_iter()
            .map(|(phase, live)| vec![phase, gib(live)])
            .collect();
        print_table(&["phase", "live GiB (max in phase)"], &rows);
        println!("peak {} GiB\n", gib(tr.peak()));
    }
    println!("paper Sec 4.4 reference: Renee init 17.9 -> peak 39.7 GiB;");
    println!("ELMO FP8 init 3.2 -> peak 6.6 GiB; ELMO BF16 init 5.2 -> peak ~10.3 GiB.");
    let r = traces[0].peak() as f64 / traces[2].peak() as f64;
    println!("model ratio Renee/FP8 = {r:.1}x (paper: ~6x)");
}
