//! Figure 5: exponent histograms of classifier weights (a) and classifier
//! inputs (b) vs the E4M3 range [-9, 8] — the evidence that weights and
//! inputs need NO tensor scaling in FP8 (paper Sec 4.3).

mod common;

use common::*;
use elmo::Session;
use elmo::coordinator::eval::diagnostics_hist;
use elmo::coordinator::{Precision, TrainConfig, Trainer};
use elmo::data::Batcher;

fn print_hist(name: &str, h: &[f32], lo: i32, lo_edge: i32, hi_edge: i32) {
    let total: f32 = h.iter().sum();
    let mut inside = 0.0f32;
    println!("-- {name} --");
    for (i, &c) in h.iter().enumerate() {
        let e = lo + i as i32;
        if c > 0.0 {
            let share = c / total * 100.0;
            if share >= 0.05 {
                let bar = "#".repeat((share / 2.0) as usize);
                println!("2^{e:>4} | {share:5.1}% {bar}");
            }
        }
        if e >= lo_edge && e <= hi_edge {
            inside += c;
        }
    }
    println!(
        "within E4M3 range [2^{lo_edge}, 2^{hi_edge}]: {:.1}%\n",
        inside / total * 100.0
    );
}

fn main() -> anyhow::Result<()> {
    if skip_banner("fig5_weight_input_hist") {
        return Ok(());
    }
    println!("== Figure 5: weight / input exponents vs E4M3 range ==\n");
    let ds = dataset("lf-amazontitles131k", 0);
    let mut sess = Session::open(ART)?;
    let cfg = TrainConfig {
        precision: Precision::Fp8,
        chunk_size: 512,
        epochs: 1,
        dropout_emb: 0.3,
        ..TrainConfig::default()
    };
    let mut tr = Trainer::new(&sess, &ds, cfg)?;
    let mut b = Batcher::new(ds.train.n, tr.batch, 0);
    for _ in 0..32 {
        let (rows, _) = b.next_batch().unwrap();
        tr.step(&mut sess, &ds, &rows)?;
    }
    let (_, hw, hx) = diagnostics_hist(&mut sess, &tr, &ds)?;
    let lo = sess.config().hist_lo;
    // E4M3: subnormal floor 2^-9, max exponent 2^8
    print_hist("Fig 5a: classifier weights", &hw, lo, -9, 8);
    print_hist("Fig 5b: classifier inputs (embeddings)", &hx, lo, -9, 8);
    println!("paper: 'most weights and classifier inputs fall within the exponent");
    println!("range of FP8 E4M3 ([-9, 8])' -> no tensor scaling required.");
    Ok(())
}
