//! Table 10 (Appendix F): chunk size vs epoch latency vs peak memory on
//! Amazon-3M with BF16 — chunking cuts transient memory by k with a flat
//! (even slightly improving) latency until k gets extreme.

mod common;

use common::*;
use elmo::Session;
use elmo::coordinator::Precision;
use elmo::data;
use elmo::memmodel::{peak_gib, MemParams, Method};
use elmo::util::print_table;

fn main() -> anyhow::Result<()> {
    if skip_banner("table10_chunking") {
        return Ok(());
    }
    println!("== Table 10: chunk count vs latency vs peak memory (Amazon-3M, BF16) ==\n");
    let prof = data::profile("amazon3m").unwrap(); // L=8192 scaled
    let ds = data::generate(&prof, 0);
    let mut sess = Session::open(ART)?;
    let epochs = epochs_or(1);
    // paper rows (chunk count k): epoch time, peak GiB
    let paper: &[(u64, &str, f64)] = &[
        (1, "13:22", 14.74),
        (2, "12:20", 14.40),
        (4, "12:12", 12.22),
        (8, "11:09", 11.13),
        (16, "11:23", 10.59),
        (32, "12:39", 10.32),
        (64, "14:19", 10.20),
        (128, "19:44", 10.20),
    ];
    let l = prof.labels; // 8192
    let mut rows = Vec::new();
    for &(k, ptime, pmem) in paper {
        let lc = (l as u64 / k) as usize;
        let res = run_training(&mut sess, &ds, Precision::Bf16, lc, epochs, 256)?;
        let mem = peak_gib(Method::ElmoBf16, &MemParams::from_profile(&prof, k));
        rows.push(vec![
            k.to_string(),
            lc.to_string(),
            mmss(res.epoch_secs),
            format!("{mem:.2}"),
            format!("{:.2}", res.report.p[0]),
            format!("{ptime} / {pmem:.2}"),
        ]);
        println!("  k={k} done");
    }
    print_table(
        &[
            "chunks k", "Lc (scaled)", "epoch (ours)", "peak GiB (model@3M)",
            "P@1", "paper epoch / GiB",
        ],
        &rows,
    );
    println!(
        "\nshape checks: peak memory falls monotonically with k and flattens\n\
         (classifier weights dominate once transients shrink); latency is flat\n\
         for moderate k and degrades at k >= 64 (per-chunk overhead)."
    );
    Ok(())
}
