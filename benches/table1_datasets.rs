//! Table 1: dataset statistics — the paper's (N, L, N', Lbar, Lhat) per
//! dataset, next to the generated scaled stand-ins.

mod common;

use elmo::data;
use elmo::util::print_table;

fn main() {
    // paper's Table 1 rows, verbatim
    let paper: &[(&str, u64, u64, u64, f64, f64)] = &[
        ("Wiki-500K", 1_779_881, 501_070, 769_421, 4.75, 16.86),
        ("AmazonTitles-670K", 485_176, 670_091, 150_875, 5.39, 5.11),
        ("Amazon-670K", 490_449, 670_091, 153_025, 5.45, 3.99),
        ("Amazon-3M", 1_717_899, 2_812_281, 742_507, 36.17, 31.64),
        ("LF-AmazonTitles-131K", 294_805, 131_073, 134_835, 5.15, 2.29),
        ("LF-WikiSeeAlso-320K", 693_082, 312_330, 177_515, 4.67, 2.11),
        ("LF-AmazonTitles-1.3M", 2_248_619, 1_305_265, 970_237, 22.2, 38.24),
        ("LF-Paper2Keywords-8.6M", 2_020_621, 8_623_847, 2_020_621, 9.03, 2.12),
    ];
    println!("== Table 1: XMC dataset statistics (paper vs generated stand-in) ==\n");
    let mut rows = Vec::new();
    for (name, n, l, nt, lbar, lhat) in paper {
        let prof = data::profiles()
            .into_iter()
            .find(|p| p.paper_name == *name)
            .unwrap();
        let ds = data::generate(&prof, 0);
        let (gn, gl, gnt, glbar, glhat) = ds.stats();
        rows.push(vec![
            name.to_string(),
            format!("{n}/{l}/{nt}"),
            format!("{lbar:.2}"),
            format!("{lhat:.2}"),
            format!("{gn}/{gl}/{gnt}"),
            format!("{glbar:.2}"),
            format!("{glhat:.2}"),
        ]);
    }
    print_table(
        &[
            "dataset",
            "paper N/L/N'",
            "Lbar",
            "Lhat",
            "ours N/L/N' (scaled)",
            "Lbar",
            "Lhat",
        ],
        &rows,
    );
    println!(
        "\nnote: stand-ins are ~1000x scaled; the preserved properties are the\n\
         Zipf head/tail mass, labels-per-instance, and train/test geometry."
    );
}
