//! Table 7: propensity-scored precision (tail-label performance) across
//! datasets and methods — low-precision training must not sacrifice tail
//! labels (paper Appendix E).

mod common;

use common::*;
use elmo::Session;
use elmo::coordinator::Precision;
use elmo::util::print_table;

fn main() -> anyhow::Result<()> {
    if skip_banner("table7_psp") {
        return Ok(());
    }
    println!("== Table 7: PSP@k (tail-label) comparison ==\n");
    let epochs = epochs_or(4);
    // paper PSP@1/3/5 for {Renee, BF16, FP8} per dataset
    let datasets: &[(&str, [[f64; 3]; 3])] = &[
        ("wiki500k", [[32.9, 42.31, 46.78], [33.32, 42.56, 47.03], [32.40, 41.68, 46.17]]),
        ("amazontitles670k", [[27.0, 31.1, 34.89], [28.62, 32.13, 35.27], [28.24, 31.88, 35.26]]),
        ("amazon3m", [[14.39, 17.47, 19.80], [15.65, 19.05, 21.6], [16.06, 19.48, 21.98]]),
        ("lf-wikiseealso320k", [[32.02, 37.07, 40.9], [31.65, 37.08, 41.04], [31.87, 36.98, 40.90]]),
        ("lf-amazontitles1.3m", [[28.54, 33.38, 36.14], [30.38, 34.59, 37.09], [26.72, 31.58, 34.46]]),
    ];
    let precisions = [Precision::Renee, Precision::Bf16, Precision::Fp8];
    let mut sess = Session::open(ART)?;
    for (name, paper) in datasets {
        let ds = dataset(name, 0);
        println!("\n--- {} ---", ds.profile.paper_name);
        let mut rows = Vec::new();
        for (pr, pvals) in precisions.iter().zip(paper.iter()) {
            let chunk = if *pr == Precision::Renee { 2048 } else { 1024 };
            let res = run_training(&mut sess, &ds, *pr, chunk, epochs, 512)?;
            let [s1, s3, s5] = fmt_psp(&res.report);
            rows.push(vec![
                pr.label().to_string(),
                s1,
                s3,
                s5,
                format!("{:.2}/{:.2}/{:.2}", pvals[0], pvals[1], pvals[2]),
            ]);
        }
        print_table(&["method", "PSP@1", "PSP@3", "PSP@5", "paper PSP@1/3/5"], &rows);
    }
    println!(
        "\nshape check: ELMO's PSP@k tracks Renee's — low-precision training\n\
         with SR is robust on tail labels (paper Appendix E)."
    );
    Ok(())
}
