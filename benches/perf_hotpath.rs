//! Perf harness (EXPERIMENTS.md §Perf): times every executable on the hot
//! path individually, then the composed step, and prints a breakdown.
//! This is the measurement side of the L3 optimization loop.
//!
//! Besides the printed tables, the run renders into `BENCH_hotpath.json`
//! (docs/BENCHMARKS.md): wall-clock kernel/step timings as ungated
//! trajectory, plus the deterministic side — `memmodel` peak bytes per
//! method, `pool_bytes` staging per worker count, and (under `--features
//! count-alloc`) Rust-side allocation counts for a fixed step sequence.
//! When artifacts are missing the report still lands, with
//! `"status": "skipped"` — the CI gate must never mistake a skipped
//! bench for a passing one.

#[cfg(feature = "count-alloc")]
#[global_allocator]
static ALLOC: elmo::bench::CountingAlloc = elmo::bench::CountingAlloc;

use elmo::Session;
use elmo::bench::{alloc_since, alloc_snapshot, counting_enabled, BenchReport};
use elmo::coordinator::{Precision, TrainConfig, Trainer};
use elmo::data;
use elmo::memmodel::{self, MemParams};
use elmo::runtime::Arg;
use elmo::util::{bench_secs, print_table, Rng};

const BENCH_NAME: &str = "hotpath";
const REPORT_PATH: &str = "BENCH_hotpath.json";

/// Fingerprint input: every knob that shapes a deterministic metric.
/// Shared verbatim between the skipped and measured paths so an ok
/// baseline and an ok re-run always compare.
const CONFIG: &str = "hotpath v1 steps=bf16:512,fp8:512,fp32:512,renee:1024 \
                      pool=bf16:256 workers=1,2,4 alloc_steps=4";

fn main() -> anyhow::Result<()> {
    let art = "artifacts";
    if elmo::session::require_artifacts(art).is_err() {
        println!("perf_hotpath: artifacts missing, skipping");
        BenchReport::skipped(BENCH_NAME, CONFIG).save(REPORT_PATH)?;
        println!("perf_hotpath: wrote {REPORT_PATH} (status: skipped)");
        return Ok(());
    }
    let mut rep = BenchReport::new(BENCH_NAME, CONFIG);
    let mut sess = Session::open(art)?;
    let mc = sess.config().clone();
    let (b, d, s, p) = (mc.batch, mc.d, mc.seq, mc.psize);
    let mut rng = Rng::new(1);

    let toks: Vec<i32> = (0..b * s).map(|_| 1 + rng.below(mc.vocab - 1) as i32).collect();
    let packed: Vec<f32> = (0..p).map(|_| rng.normal_f32(0.0, 0.02)).collect();
    let zeros = vec![0.0f32; p];
    let emb: Vec<f32> = (0..b * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();

    let mut rows: Vec<Vec<String>> = Vec::new();

    // encoder fwd/bwd per precision
    for prec in ["fp32", "bf16", "fp8"] {
        let name = format!("enc_fwd_{prec}");
        let secs = {
            let rt = sess.runtime();
            bench_secs(1.0, 50, || {
                rt.exec(
                    &name,
                    &[Arg::F32(&packed), Arg::I32(&toks), Arg::I32(&[1]), Arg::F32(&[0.0])],
                )
                .unwrap();
            })
        };
        rep.wall_f64(&format!("kernel/{name}/ms"), secs * 1e3)?;
        rows.push(vec![name, format!("{:.2}", secs * 1e3), format!("{:.1}/s", 1.0 / secs)]);
        let name = format!("enc_bwd_{prec}");
        let secs = {
            let rt = sess.runtime();
            bench_secs(1.5, 30, || {
                rt.exec(
                    &name,
                    &[
                        Arg::F32(&packed),
                        Arg::F32(&zeros),
                        Arg::F32(&zeros),
                        Arg::F32(&zeros),
                        Arg::I32(&toks),
                        Arg::F32(&emb),
                        Arg::F32(&[1e-3]),
                        Arg::F32(&[0.01]),
                        Arg::F32(&[1.0]),
                        Arg::I32(&[1]),
                        Arg::F32(&[0.0]),
                    ],
                )
                .unwrap();
            })
        };
        rep.wall_f64(&format!("kernel/{name}/ms"), secs * 1e3)?;
        rows.push(vec![name, format!("{:.2}", secs * 1e3), format!("{:.1}/s", 1.0 / secs)]);
    }

    // classifier chunk kernels across sizes
    for (cfg, lc) in [
        ("fp32", 1024usize),
        ("bf16", 256),
        ("bf16", 1024),
        ("bf16", 4096),
        ("fp8", 1024),
    ] {
        let name = format!("cls_chunk_{cfg}_{lc}");
        let w: Vec<f32> = (0..lc * d).map(|_| rng.normal_f32(0.0, 0.05)).collect();
        let y = vec![0.0f32; b * lc];
        let secs = {
            let rt = sess.runtime();
            bench_secs(1.0, 50, || {
                rt.exec(
                    &name,
                    &[
                        Arg::F32(&w),
                        Arg::F32(&emb),
                        Arg::F32(&y),
                        Arg::F32(&[0.05]),
                        Arg::I32(&[3]),
                        Arg::F32(&[0.0]),
                    ],
                )
                .unwrap();
            })
        };
        rep.wall_f64(&format!("kernel/{name}/ms"), secs * 1e3)?;
        rows.push(vec![
            name,
            format!("{:.2}", secs * 1e3),
            format!("{:.1} Mlabel/s", (b * lc) as f64 / secs / 1e6),
        ]);
    }

    // scoring
    {
        let lc = 1024;
        let w: Vec<f32> = (0..lc * d).map(|_| rng.normal_f32(0.0, 0.05)).collect();
        let secs = {
            let rt = sess.runtime();
            bench_secs(1.0, 100, || {
                rt.exec("cls_fwd_1024", &[Arg::F32(&w), Arg::F32(&emb)])
                    .unwrap();
            })
        };
        rep.wall_f64("kernel/cls_fwd_1024/ms", secs * 1e3)?;
        rows.push(vec!["cls_fwd_1024".into(), format!("{:.2}", secs * 1e3), format!("{:.1}/s", 1.0 / secs)]);
    }

    println!("\n== executable-level hot path ==");
    print_table(&["executable", "ms/call", "rate"], &rows);

    // memmodel peak bytes per method at the paper's Sec 4.4 walkthrough:
    // the analytic side of the hot path, exact integers, gated exactly
    for (method, tag) in elmo::bench::scenario::MEM_METHODS {
        rep.det_u64(
            &format!("memmodel/{tag}/peak_bytes"),
            memmodel::peak_bytes(method, &MemParams::paper_example()),
        )?;
    }

    // composed training step on the quickstart profile
    let prof = data::profile("quickstart").unwrap();
    let ds = data::generate(&prof, 1);
    for (prec, chunk, tag) in [
        (Precision::Bf16, 512usize, "bf16"),
        (Precision::Fp8, 512, "fp8"),
        (Precision::Fp32, 512, "fp32"),
        (Precision::Renee, 1024, "renee"),
    ] {
        let cfg = TrainConfig { precision: prec, chunk_size: chunk, ..TrainConfig::default() };
        let mut tr = Trainer::new(&sess, &ds, cfg)?;
        let rows_b: Vec<u32> = (0..tr.batch as u32).collect();
        let secs = {
            let sess = &mut sess;
            let ds = &ds;
            bench_secs(2.0, 20, || {
                tr.step(sess, ds, &rows_b).unwrap();
            })
        };
        rep.wall_f64(&format!("step/{tag}/steps_per_s"), 1.0 / secs)?;
        println!(
            "step[{:22}] {:6.1} ms  ({:.2} steps/s, {:.0} labels/s)",
            prec.label(),
            secs * 1e3,
            1.0 / secs,
            (prof.labels * tr.batch) as f64 / secs
        );
    }

    // parallel chunk engine: the same composed step with label chunks
    // fanned out to the session's pool (bit-identical results — see
    // rust/tests/parallel_parity.rs; this measures the speedup side)
    println!("\n== parallel chunk engine (bf16, Lc=256 -> 4 chunks/step) ==");
    let cfg = TrainConfig {
        precision: Precision::Bf16,
        chunk_size: 256,
        ..TrainConfig::default()
    };
    let mut serial_secs = 0.0f64;
    for workers in [1usize, 2, 4] {
        // one Session per worker count: the same unified API serves the
        // serial (workers = 1, pool-less) and pooled configurations
        let mut wsess = Session::builder().artifacts(art).workers(workers).build()?;
        let mut tr = Trainer::new(&wsess, &ds, cfg.clone())?;
        wsess.prepare(&tr.required_kernels())?;
        let rows_b: Vec<u32> = (0..tr.batch as u32).collect();
        let staging = memmodel::pool_bytes(&tr.store, tr.batch, workers);
        rep.det_u64(&format!("pool/workers{workers}/staging_bytes"), staging as u64)?;
        let secs = {
            let wsess = &mut wsess;
            let ds = &ds;
            bench_secs(2.0, 20, || {
                tr.step(wsess, ds, &rows_b).unwrap();
            })
        };
        rep.wall_f64(&format!("pool/workers{workers}/steps_per_s"), 1.0 / secs)?;
        if workers == 1 {
            serial_secs = secs;
        }
        println!(
            "step[workers={workers}] {:6.1} ms  ({:.2} steps/s, {:.2}x serial, +{} KiB staging)",
            secs * 1e3,
            1.0 / secs,
            serial_secs / secs,
            staging >> 10
        );
    }

    // allocation counts over a FIXED step sequence (bench_secs adapts its
    // iteration count to wall time, which would make counts substrate-
    // dependent; a pinned 4-step window replays)
    if counting_enabled() {
        let cfg = TrainConfig {
            precision: Precision::Bf16,
            chunk_size: 512,
            ..TrainConfig::default()
        };
        let mut tr = Trainer::new(&sess, &ds, cfg)?;
        let rows_b: Vec<u32> = (0..tr.batch as u32).collect();
        tr.step(&mut sess, &ds, &rows_b)?; // warm caches/capacities
        let a0 = alloc_snapshot();
        for _ in 0..4 {
            tr.step(&mut sess, &ds, &rows_b)?;
        }
        let da = alloc_since(a0);
        rep.det_u64_pct("alloc/step4_calls", da.calls, 20.0)?;
        rep.det_u64_pct("alloc/step4_bytes", da.bytes, 20.0)?;
        println!(
            "\nalloc[bf16 step x4] {} calls, {} bytes (rust-side only)",
            da.calls, da.bytes
        );
    }

    rep.save(REPORT_PATH)?;
    println!("\nperf_hotpath: wrote {REPORT_PATH}");
    Ok(())
}
