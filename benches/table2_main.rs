//! Table 2: the paper's main results — P@{1,3,5}, peak training memory,
//! and epoch time for six datasets x {sampling baseline, Renee, ELMO BF16,
//! ELMO FP8}.
//!
//! Columns reported here:
//!   paper P@1       the paper's number (verbatim, for reference)
//!   ours P@k        measured on the scaled synthetic stand-in
//!   M_tr (model)    peak memory at PAPER scale from the allocation model
//!   paper M_tr      the paper's measured GiB
//!   epoch           measured on this CPU testbed (relative ordering only)

mod common;

use common::*;
use elmo::Session;
use elmo::coordinator::Precision;
use elmo::util::print_table;

struct PaperRow {
    method: &'static str,
    p1: f64,
    mtr: f64,
}

fn main() -> anyhow::Result<()> {
    if skip_banner("table2_main") {
        return Ok(());
    }
    println!("== Table 2: main precision/memory/time comparison ==\n");
    let epochs = epochs_or(5);
    // (profile, paper rows [sampling-best, renee, bf16, fp8])
    let datasets: &[(&str, [PaperRow; 4])] = &[
        ("wiki500k", [
            PaperRow { method: "CascadeXML", p1: 77.0, mtr: 18.8 },
            PaperRow { method: "Renee", p1: 78.69, mtr: 12.69 },
            PaperRow { method: "ELMO (BF16)", p1: 78.61, mtr: 7.21 },
            PaperRow { method: "ELMO (FP8)", p1: 78.39, mtr: 5.01 },
        ]),
        ("amazontitles670k", [
            PaperRow { method: "CascadeXML", p1: 42.1, mtr: 22.3 },
            PaperRow { method: "Renee", p1: 43.78, mtr: 12.46 },
            PaperRow { method: "ELMO (BF16)", p1: 44.3, mtr: 5.12 },
            PaperRow { method: "ELMO (FP8)", p1: 44.39, mtr: 3.37 },
        ]),
        ("amazon670k", [
            PaperRow { method: "CascadeXML", p1: 48.5, mtr: 18.3 },
            PaperRow { method: "Renee", p1: 50.6, mtr: 11.91 },
            PaperRow { method: "ELMO (BF16)", p1: 50.7, mtr: 5.29 },
            PaperRow { method: "ELMO (FP8)", p1: 50.34, mtr: 3.3 },
        ]),
        ("amazon3m", [
            PaperRow { method: "CascadeXML", p1: 51.3, mtr: 87.0 },
            PaperRow { method: "Renee", p1: 52.6, mtr: 39.7 },
            PaperRow { method: "ELMO (BF16)", p1: 53.4, mtr: 10.39 },
            PaperRow { method: "ELMO (FP8)", p1: 52.73, mtr: 6.6 },
        ]),
        ("lf-wikiseealso320k", [
            PaperRow { method: "DEXML", p1: 46.78, mtr: 38.6 },
            PaperRow { method: "Renee", p1: 47.86, mtr: 13.89 },
            PaperRow { method: "ELMO (BF16)", p1: 47.84, mtr: 6.57 },
            PaperRow { method: "ELMO (FP8)", p1: 47.88, mtr: 5.2 },
        ]),
        ("lf-amazontitles1.3m", [
            PaperRow { method: "DEXML", p1: 58.4, mtr: 75.53 },
            PaperRow { method: "Renee", p1: 56.04, mtr: 19.9 },
            PaperRow { method: "ELMO (BF16)", p1: 56.14, mtr: 6.61 },
            PaperRow { method: "ELMO (FP8)", p1: 54.97, mtr: 4.31 },
        ]),
    ];
    let precisions = [
        Precision::Sampled,
        Precision::Renee,
        Precision::Bf16,
        Precision::Fp8,
    ];

    let mut sess = Session::open(ART)?;
    for (name, paper_rows) in datasets {
        let ds = dataset(name, 0);
        println!("\n--- {} ({}) ---", ds.profile.paper_name, name);
        let mut rows = Vec::new();
        for (pr, paper) in precisions.iter().zip(paper_rows.iter()) {
            let chunk = if *pr == Precision::Renee { 2048 } else { 1024 };
            let res = run_training(&mut sess, &ds, *pr, chunk, epochs, 512)?;
            let [p1, p3, p5] = fmt_p(&res.report);
            let mem = paper_mem_gib(&ds.profile, method_of(*pr), res.trainer_chunks as u64);
            rows.push(vec![
                pr.label().to_string(),
                p1,
                p3,
                p5,
                format!("{:.2}", mem),
                format!("{:.2}", paper.mtr),
                mmss(res.epoch_secs),
                format!("{:.2} ({})", paper.p1, paper.method),
            ]);
        }
        print_table(
            &[
                "method", "P@1", "P@3", "P@5", "M_tr model GiB", "M_tr paper GiB",
                "epoch (ours)", "paper P@1",
            ],
            &rows,
        );
    }
    println!(
        "\nshape checks: ELMO ~= Renee accuracy at a fraction of the memory;\n\
         the sampling baseline trails end-to-end methods; FP8 slightly\n\
         below BF16 on some datasets (paper Table 2)."
    );
    Ok(())
}
