//! Table 4: BF16 vs FP8 *encoder* with the classifier fixed at FP8 —
//! precision is similar; FP8 costs some time in the mixed recipe.

mod common;

use common::*;
use elmo::Session;
use elmo::coordinator::{Precision, TrainConfig};
use elmo::data;
use elmo::memmodel::{peak_gib, MemParams, Method};
use elmo::util::print_table;

fn main() -> anyhow::Result<()> {
    if skip_banner("table4_encoder_prec") {
        return Ok(());
    }
    println!("== Table 4: encoder precision with FP8 classifier ==\n");
    let mut sess = Session::open(ART)?;
    let epochs = epochs_or(4);
    // paper rows: (profile, enc, P@1, M_tr GB, epoch)
    let paper: &[(&str, &str, f64, f64, &str)] = &[
        ("lf-amazontitles1.3m", "bf16", 55.08, 5.50, "17:26"),
        ("lf-amazontitles1.3m", "fp8", 54.97, 4.63, "17:44"),
        ("amazon3m", "bf16", 52.60, 8.51, "15:56"),
        ("amazon3m", "fp8", 52.73, 7.16, "18:02"),
    ];
    let mut rows = Vec::new();
    for &(name, enc, pp1, pmtr, ptime) in paper {
        let prof = data::profile(name).unwrap();
        let ds = data::generate(&prof, 0);
        let cfg = TrainConfig {
            precision: Precision::Fp8,
            enc_override: Some(if enc == "bf16" { "bf16" } else { "fp8" }),
            chunk_size: 1024,
            epochs,
            dropout_emb: 0.3,
            ..TrainConfig::default()
        };
        let res = run_training_cfg(&mut sess, &ds, cfg, 512)?;
        let method = if enc == "bf16" { Method::Fp8ClsBf16Enc } else { Method::ElmoFp8 };
        let mem = peak_gib(method, &MemParams::from_profile(&prof, res.trainer_chunks as u64));
        let [p1, p3, p5] = fmt_p(&res.report);
        rows.push(vec![
            prof.paper_name.to_string(),
            enc.to_uppercase(),
            p1,
            p3,
            p5,
            format!("{mem:.2}"),
            mmss(res.epoch_secs),
            format!("{pp1:.2} / {pmtr:.2} GB / {ptime}"),
        ]);
    }
    print_table(
        &[
            "dataset", "encoder", "P@1", "P@3", "P@5",
            "M_tr model GiB", "epoch (ours)", "paper P@1 / M_tr / epoch",
        ],
        &rows,
    );
    println!("\nshape check: accuracies within noise of each other; FP8 encoder saves");
    println!("memory but NOT time (the FP8<->BF16 recipe overhead — paper Sec 6).");
    Ok(())
}
