//! Table 8: LF-AmazonTitles-131K — P@k + PSP@k + memory + epoch time for
//! sampling baselines vs Renee vs ELMO.

mod common;

use common::*;
use elmo::Session;
use elmo::coordinator::Precision;
use elmo::util::print_table;

fn main() -> anyhow::Result<()> {
    if skip_banner("table8_amazontitles131k") {
        return Ok(());
    }
    println!("== Table 8: LF-AmazonTitles-131K ==\n");
    let ds = dataset("lf-amazontitles131k", 0);
    let mut sess = Session::open(ART)?;
    let epochs = epochs_or(5);
    // paper rows: (label, P@1, PSP@1, M_tr, epoch)
    let paper: &[(&str, Precision, f64, f64, f64, &str)] = &[
        ("NGAME~(sampled)", Precision::Sampled, 44.69, 38.81, 11.03, "5:15"),
        ("RENEE", Precision::Renee, 46.05, 39.08, 5.53, "0:33"),
        ("ELMO (BF16)", Precision::Bf16, 45.6, 38.84, 3.41, "0:31"),
        ("ELMO (FP8)", Precision::Fp8, 45.45, 38.75, 2.75, "0:22"),
    ];
    let mut rows = Vec::new();
    for &(pname, pr, pp1, ppsp1, pmtr, ptime) in paper {
        let chunk = if pr == Precision::Renee { 2048 } else { 1024 };
        let res = run_training(&mut sess, &ds, pr, chunk, epochs, 768)?;
        let [p1, p3, p5] = fmt_p(&res.report);
        let [s1, _, s5] = fmt_psp(&res.report);
        let mem = paper_mem_gib(&ds.profile, method_of(pr), res.trainer_chunks as u64);
        rows.push(vec![
            pname.to_string(),
            p1, p3, p5, s1, s5,
            format!("{mem:.2}"),
            mmss(res.epoch_secs),
            format!("{pp1:.2}/{ppsp1:.2} @ {pmtr} GiB, {ptime}"),
        ]);
    }
    print_table(
        &[
            "method", "P@1", "P@3", "P@5", "PSP@1", "PSP@5",
            "M_tr model", "epoch (ours)", "paper P@1/PSP@1 @ M_tr, epoch",
        ],
        &rows,
    );
    println!("\nshape check: end-to-end methods cluster together above the sampled");
    println!("baseline; FP8 is the smallest footprint (paper: 2.75 GiB).");
    Ok(())
}
