//! Figure 4: peak GPU memory vs label count (131K -> 18M) for Renee vs
//! ELMO BF16/FP8.  Beyond 8.6M the paper appends random labels — here the
//! label count is simply a model parameter.

mod common;

use elmo::memmodel::{peak_gib, MemParams, Method};
use elmo::util::print_table;

fn main() {
    println!("== Figure 4: peak memory vs label size (BERT-base, b=128, k=8) ==\n");
    let sizes: &[(u64, &str)] = &[
        (131_073, "131K"),
        (501_070, "500K"),
        (670_091, "670K"),
        (1_305_265, "1.3M"),
        (2_812_281, "3M"),
        (8_623_847, "8.6M"),
        (13_000_000, "13M"),
        (18_000_000, "18M"),
    ];
    let mut rows = Vec::new();
    for &(labels, tag) in sizes {
        let mut p = MemParams::paper_example();
        p.labels = labels;
        let renee = peak_gib(Method::Renee, &p);
        let bf16 = peak_gib(Method::ElmoBf16, &p);
        let fp8 = peak_gib(Method::ElmoFp8, &p);
        rows.push(vec![
            tag.to_string(),
            format!("{renee:.1}"),
            format!("{bf16:.1}"),
            format!("{fp8:.1}"),
            format!("{:.1}x", renee / fp8),
        ]);
    }
    print_table(
        &["labels", "Renee GiB", "ELMO BF16 GiB", "ELMO FP8 GiB", "Renee/FP8"],
        &rows,
    );
    println!(
        "\npaper reference ratios: ~6x at 3M, ~11x at 8.6M, ~13x at 18M\n\
         (the ratio grows because Renee's per-label cost is 20 B vs FP8's ~1.3 B)."
    );
}
