//! Figure 2a: P@1 over the (exponent, mantissa) grid for classifier-weight
//! storage, with round-to-nearest-even below the diagonal and stochastic
//! rounding above it.
//!
//! Protocol: train with exact fp32 updates, then snap the classifier onto
//! the (E, M) grid after every step — exactly "storing the weights in that
//! format" (the host softfloat is bit-identical to the Pallas quantizer;
//! see rust/tests/integration.rs::quant_sweep_artifact_matches_rust_softfloat).
//!
//! Expected shape (paper): >=3 exponent bits needed; RNE degrades below
//! ~6 mantissa bits; SR recovers the loss down to very few bits.

mod common;

use common::*;
use elmo::Session;
use elmo::coordinator::{evaluate, Precision, TrainConfig, Trainer};
use elmo::data::Batcher;
use elmo::util::print_table;

fn main() -> anyhow::Result<()> {
    if skip_banner("fig2a_bitwidth_grid") {
        return Ok(());
    }
    println!("== Figure 2a: P@1 across (E, M) classifier-weight formats ==\n");
    let ds = dataset("lf-amazontitles131k", 0);
    let mut sess = Session::open(ART)?;
    let epochs = epochs_or(2);
    let e_grid = [2u32, 3, 4, 5];
    let m_grid = [1u32, 2, 3, 5, 7];

    let mut table: Vec<Vec<String>> = Vec::new();
    for &sr in &[false, true] {
        for &e in &e_grid {
            let mut row = vec![format!("E{e} {}", if sr { "SR" } else { "RNE" })];
            for &m in &m_grid {
                let cfg = TrainConfig {
                    precision: Precision::Fp32,
                    chunk_size: 512,
                    epochs,
                    dropout_emb: 0.3,
                    ..TrainConfig::default()
                };
                let mut tr = Trainer::new(&sess, &ds, cfg)?;
                for epoch in 0..epochs {
                    let mut b = Batcher::new(ds.train.n, tr.batch, epoch as u64);
                    while let Some((rows, _)) = b.next_batch() {
                        tr.step(&mut sess, &ds, &rows)?;
                        tr.quantize_classifier(e, m, sr);
                    }
                }
                let rep = evaluate(&mut sess, &tr, &ds, 256)?;
                row.push(format!("{:.1}", rep.p[0]));
            }
            table.push(row);
            println!("  done E{e} sr={sr}");
        }
    }
    let mut header = vec!["format".to_string()];
    header.extend(m_grid.iter().map(|m| format!("M{m}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    println!();
    print_table(&header_refs, &table);
    println!(
        "\npaper shape to check: E2 rows collapse (range clipping); with RNE,\n\
         P@1 drops as M shrinks; the SR rows stay near the full-precision value."
    );
    Ok(())
}
