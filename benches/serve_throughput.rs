//! Seeded serve-throughput bench: the artifact-free perf trajectory.
//!
//! Replays the `elmo::bench::scenario` grid — `LoadGen` arrivals through
//! the production `serve::replay` event loop on the `VirtualClock`, per
//! rate {500, 4000} x burst {1, 6} x label shards {1, 2, 4} — and renders
//! it into `BENCH_serve_throughput.json`.  No PJRT, no artifacts, no
//! wall-clock sleeps: every packing digest, results digest, and counter
//! in the report replays bit-identically on any machine, which is what
//! lets the CI perf gate diff this report against the committed baseline
//! on every push (rust/tests/serve_queue.rs pins the contract).
//!
//! Build with `--features count-alloc` to add Rust-side allocation counts
//! for the grid (deterministic, pct-gated — see docs/BENCHMARKS.md).

#[cfg(feature = "count-alloc")]
#[global_allocator]
static ALLOC: elmo::bench::CountingAlloc = elmo::bench::CountingAlloc;

use elmo::bench::{
    self, ARRIVAL_SEED, BURSTS, CACHE_CELLS, RATES, REPLICA_COUNTS, SHARDS, SHORTLIST_PROBES,
};
use elmo::serve::RoutePolicy;
use elmo::util::print_table;

fn main() -> anyhow::Result<()> {
    // warm one cell first so allocator/lazy-init noise stays out of the
    // counted grid
    let _ = bench::run_cell(RATES[0] as f64, BURSTS[0], SHARDS[0], ARRIVAL_SEED)?;

    let rep = bench::serve_throughput_report(ARRIVAL_SEED)?;

    let mut rows = Vec::new();
    for rate in RATES {
        for burst in BURSTS {
            for sh in SHARDS {
                let cell = bench::run_cell(rate as f64, burst, sh, ARRIVAL_SEED)?;
                let s = &cell.stats;
                rows.push(vec![
                    format!("r{rate}/b{burst}/s{sh}"),
                    s.completed().to_string(),
                    s.rejected.to_string(),
                    s.core.batches.to_string(),
                    s.deadline_flushes.to_string(),
                    format!("{:.2}", cell.virt_p50_ms),
                    format!("{:.2}", cell.virt_p99_ms),
                    format!("{:016x}", s.packing_digest()),
                ]);
            }
        }
    }
    println!("== serve throughput grid (seed {ARRIVAL_SEED}, virtual clock) ==");
    print_table(
        &["cell", "done", "rej", "batches", "deadline", "p50 ms", "p99 ms", "packing digest"],
        &rows,
    );

    // shortlist cells: the two-stage scanner on the zero-rejection corner
    let mut sl_rows = Vec::new();
    for probe in SHORTLIST_PROBES {
        let cell = bench::run_shortlist_cell(probe, ARRIVAL_SEED)?;
        let s = &cell.stats;
        sl_rows.push(vec![
            format!("sl/p{probe}"),
            s.completed().to_string(),
            s.core.batches.to_string(),
            s.chunks_scanned.to_string(),
            format!("{}/{}", cell.recall_hits, cell.recall_total),
            cell.index_bytes.to_string(),
            format!("{:016x}", cell.results_digest),
        ]);
    }
    println!("== shortlist cells (exact twin r4000/b1 scans batches x 4 chunks) ==");
    print_table(
        &["cell", "done", "batches", "chunks", "recall", "index B", "results digest"],
        &sl_rows,
    );

    // replica cells: both routing policies over the same corner — the
    // results digest column must match r4000/b1/s1 above, line for line
    let mut rep_rows = Vec::new();
    for policy in [RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded] {
        let tag = match policy {
            RoutePolicy::RoundRobin => "rr",
            RoutePolicy::LeastLoaded => "ll",
        };
        for replicas in REPLICA_COUNTS {
            let cell = bench::run_replica_cell(replicas, policy, ARRIVAL_SEED)?;
            let s = &cell.stats;
            let routed: Vec<String> =
                s.replica_batches.iter().map(|b| b.to_string()).collect();
            rep_rows.push(vec![
                format!("rep/{tag}{replicas}"),
                s.completed().to_string(),
                s.core.batches.to_string(),
                format!("[{}]", routed.join(" ")),
                cell.replica_bytes.to_string(),
                format!("{:016x}", cell.results_digest),
            ]);
        }
    }
    println!("== replica cells (routing chooses who scans, never what) ==");
    print_table(&["cell", "done", "batches", "routed", "replica B", "results digest"], &rep_rows);

    // cache cells: Zipf hot-key mixes through the swap-aware cached scan
    let mut cache_rows = Vec::new();
    for (tag, zipf_keys, zipf_s, cap, swap_at_ms, ramp_period_ms) in CACHE_CELLS {
        let cell =
            bench::run_cache_cell(zipf_keys, zipf_s, cap, swap_at_ms, ramp_period_ms, ARRIVAL_SEED)?;
        let s = &cell.stats;
        cache_rows.push(vec![
            format!("cache/{tag}"),
            s.completed().to_string(),
            s.core.batches.to_string(),
            s.chunks_scanned.to_string(),
            format!("{}/{}", s.cache_hits, s.cache_lookups),
            s.cache_evictions.to_string(),
            s.cache_batch_skips.to_string(),
            format!("v{}", s.model_version),
            format!("{:016x}", cell.results_digest),
        ]);
    }
    println!("== cache cells (seeded Zipf mixes, swap-aware cached scan) ==");
    print_table(
        &["cell", "done", "batches", "chunks", "hit/look", "evict", "skips", "ver", "results digest"],
        &cache_rows,
    );

    // traced cells: the observability seam's determinism witnesses.  The
    // gated digests below are pinned in the report; the Chrome traces are
    // written next to it so CI can trace-check and archive them.
    let mut trace_rows = Vec::new();
    for (tag, path, cell) in [
        ("trace/replay", "TRACE_serve_replay.json", bench::run_traced_cell(ARRIVAL_SEED)?),
        ("trace/cache_swap", "TRACE_cache_swap.json", bench::run_traced_swap_cell(ARRIVAL_SEED)?),
    ] {
        std::fs::write(path, &cell.chrome_json)?;
        trace_rows.push(vec![
            tag.to_string(),
            cell.events.to_string(),
            cell.stats.core.batches.to_string(),
            format!("{:016x}", cell.gated_digest),
            path.to_string(),
        ]);
    }
    println!("== traced cells (gated digest = FNV-1a over the virtual-time event stream) ==");
    print_table(&["cell", "events", "batches", "gated digest", "trace"], &trace_rows);

    rep.save("BENCH_serve_throughput.json")?;
    println!(
        "serve_throughput: wrote BENCH_serve_throughput.json \
         ({} metrics, fingerprint {})",
        rep.metrics.len(),
        rep.fingerprint
    );
    Ok(())
}
