//! Seeded serve-throughput bench: the artifact-free perf trajectory.
//!
//! Replays the `elmo::bench::scenario` grid — `LoadGen` arrivals through
//! the production `serve::replay` event loop on the `VirtualClock`, per
//! rate {500, 4000} x burst {1, 6} x label shards {1, 2, 4} — and renders
//! it into `BENCH_serve_throughput.json`.  No PJRT, no artifacts, no
//! wall-clock sleeps: every packing digest, results digest, and counter
//! in the report replays bit-identically on any machine, which is what
//! lets the CI perf gate diff this report against the committed baseline
//! on every push (rust/tests/serve_queue.rs pins the contract).
//!
//! Build with `--features count-alloc` to add Rust-side allocation counts
//! for the grid (deterministic, pct-gated — see docs/BENCHMARKS.md).

#[cfg(feature = "count-alloc")]
#[global_allocator]
static ALLOC: elmo::bench::CountingAlloc = elmo::bench::CountingAlloc;

use elmo::bench::{self, ARRIVAL_SEED, BURSTS, RATES, SHARDS, SHORTLIST_PROBES};
use elmo::util::print_table;

fn main() -> anyhow::Result<()> {
    // warm one cell first so allocator/lazy-init noise stays out of the
    // counted grid
    let _ = bench::run_cell(RATES[0] as f64, BURSTS[0], SHARDS[0], ARRIVAL_SEED)?;

    let rep = bench::serve_throughput_report(ARRIVAL_SEED)?;

    let mut rows = Vec::new();
    for rate in RATES {
        for burst in BURSTS {
            for sh in SHARDS {
                let cell = bench::run_cell(rate as f64, burst, sh, ARRIVAL_SEED)?;
                let s = &cell.stats;
                rows.push(vec![
                    format!("r{rate}/b{burst}/s{sh}"),
                    s.completed().to_string(),
                    s.rejected.to_string(),
                    s.core.batches.to_string(),
                    s.deadline_flushes.to_string(),
                    format!("{:.2}", cell.virt_p50_ms),
                    format!("{:.2}", cell.virt_p99_ms),
                    format!("{:016x}", s.packing_digest()),
                ]);
            }
        }
    }
    println!("== serve throughput grid (seed {ARRIVAL_SEED}, virtual clock) ==");
    print_table(
        &["cell", "done", "rej", "batches", "deadline", "p50 ms", "p99 ms", "packing digest"],
        &rows,
    );

    // shortlist cells: the two-stage scanner on the zero-rejection corner
    let mut sl_rows = Vec::new();
    for probe in SHORTLIST_PROBES {
        let cell = bench::run_shortlist_cell(probe, ARRIVAL_SEED)?;
        let s = &cell.stats;
        sl_rows.push(vec![
            format!("sl/p{probe}"),
            s.completed().to_string(),
            s.core.batches.to_string(),
            s.chunks_scanned.to_string(),
            format!("{}/{}", cell.recall_hits, cell.recall_total),
            cell.index_bytes.to_string(),
            format!("{:016x}", cell.results_digest),
        ]);
    }
    println!("== shortlist cells (exact twin r4000/b1 scans batches x 4 chunks) ==");
    print_table(
        &["cell", "done", "batches", "chunks", "recall", "index B", "results digest"],
        &sl_rows,
    );

    rep.save("BENCH_serve_throughput.json")?;
    println!(
        "serve_throughput: wrote BENCH_serve_throughput.json \
         ({} metrics, fingerprint {})",
        rep.metrics.len(),
        rep.fingerprint
    );
    Ok(())
}
